//! L3 decode-serving coordinator.
//!
//! The serving shape of the paper's contribution: AMLA is a decode
//! kernel whose throughput comes from keeping the matmul units
//! saturated across **many concurrent decode requests**, so the
//! coordinator is a vLLM-style *batched* decode loop with the kernel as
//! its hot path:
//!
//! ```text
//! requests → [batcher: admission + continuous batching]
//!          → [scheduler: one batched step per iteration — decoding
//!             sequences advance one token, prefilling sequences a
//!             prompt chunk of up to `prefill_chunk` tokens]
//!          → [engine: N-layer MLA model; step_batch fans the per-
//!             sequence attention calls over a scoped worker pool]
//!          → [kvcache: paged latent pool, page-contiguous gather into
//!             bucket buffers]
//!          → streamed tokens + metrics (per-batch occupancy; the step
//!             latency histogram is per batched step)
//! ```
//!
//! `docs/ARCHITECTURE.md` walks one batched decode step and one chunked
//! prefill step through this stack end to end, and indexes every
//! bit-identity contract with its pinning tests.
//!
//! ## The batched-engine contract
//!
//! [`engine::LayerExecutor::step_batch`] advances a whole batch of
//! [`engine::StepJob`]s one layer forward.  Three rules make it safe to
//! parallelize and easy to implement:
//!
//! 1. **Default = serial reference.**  The provided implementation
//!    loops over [`engine::LayerExecutor::step`]; any executor (e.g.
//!    [`engine::PjrtLayerExecutor`]) works unmodified.
//! 2. **Bit-identical parallelism.**  Jobs are independent — disjoint
//!    caches, disjoint outputs — so a parallel implementation must (and
//!    [`engine::HostLayerExecutor`]'s scoped-thread pool does) return
//!    exactly the serial results for every worker count.
//!    `rust/tests/end_to_end.rs` pins this bit-for-bit.
//! 3. **Scratch reuse.**  Per-block buffers of the attention recurrence
//!    live in [`crate::numerics::amla::AmlaScratch`], one per worker,
//!    reused across layers and steps — the hot loop performs no heap
//!    allocation.
//!
//! Worker count comes from [`crate::config::ServeConfig::batch_workers`]
//! (`--batch-workers`; 1 = serial).  The older
//! [`crate::config::ServeConfig::workers`] field still sizes the PJRT
//! client pool.
//!
//! ## The fused-path bit-identity contract
//!
//! With [`crate::config::ServeConfig::fuse_buckets`] on
//! (`--fuse-buckets`, the default), [`engine::HostLayerExecutor`]
//! groups a step's jobs by KV bucket and runs each group of ≥ 2 through
//! **one** cross-sequence kernel call
//! ([`crate::numerics::amla::amla_attention_batched`] /
//! [`crate::numerics::flash_base::base_flash_attention_batched`]): the
//! absorbed queries stack into a `[B·G, Dk]` block, the packed keys
//! gather into a reusable [`crate::kvcache::BucketArena`], and a single
//! score/rescale/accumulate block loop covers the whole group.
//! Singleton buckets fall back to the threaded per-sequence path.
//!
//! Fusion must be **bit-identical** to the per-sequence path, not just
//! close: per-row `AmlaState` semantics (Δn clamps, `ROUND_EPS`
//! tie-breaks, zero-mass no-ops) are preserved across the stacked
//! dimension, and the score / `P·V` matmuls run per-sequence slabs with
//! the exact per-sequence operand shapes.  Three layers of tests pin
//! the contract: kernel-level property suites (fused ≡ N× per-sequence,
//! bit-for-bit, 100+ randomized mask/precision cases), the end-to-end
//! `(fuse, workers, max_batch)` serving matrix, and the golden-trace
//! file under `rust/tests/golden/` that freezes tokens *and* final
//! residual bits across PRs.  A change that breaks any of these is a
//! numerics regression, never an acceptable "parallel rounding
//! difference".
//!
//! ## The chunked-prefill bit-identity contract
//!
//! Prompts prefill **chunk-at-a-time**: a prefilling sequence consumes
//! up to [`crate::config::ServeConfig::prefill_chunk`] prompt tokens
//! per global step (`--prefill-chunk`, default 8; 1 = the legacy
//! token-per-step path), carried as [`engine::StepJob::sq`] rows
//! through one multi-row causal attention pass
//! ([`crate::numerics::amla::amla_prefill_chunk`] /
//! [`crate::numerics::flash_base::base_prefill_chunk`]).  Chunking
//! amortizes the per-invocation layer overhead a long prompt otherwise
//! pays per token, and makes recompute-style preemption resume
//! (`prompt ⧺ generated` re-prefill, [`crate::serving::preempt`])
//! proportionally cheaper.
//!
//! Like fusion, chunking must be **bit-identical** — cache state and
//! next-token readout exactly equal to `C` single-token steps, for
//! every chunk size, even when the token-by-token run would have
//! crossed KV buckets mid-chunk (masked bucket-padding blocks are
//! exact no-ops).  Executors advertise multi-row support via
//! [`engine::LayerExecutor::max_prefill_chunk`]; the scheduler clamps
//! to it, so [`engine::PjrtLayerExecutor`] (fixed-`sq` executables)
//! transparently falls back to token-by-token.  Pinned by the kernel
//! property suites (`prop_prefill_chunk_equals_token_by_token`, both
//! algorithms, both precisions), the engine suite
//! (`chunked_prefill_bit_identical_to_token_steps`, chunk sizes
//! 1/3/page/page+1), and the open-loop chunk reruns in
//! `rust/tests/open_loop_golden.rs`.
//!
//! ## One stepping core, one session loop
//!
//! The engine-stepping machinery (batched step + token accounting +
//! reap/release/evict/cancel) lives once in [`scheduler::StepCore`],
//! and since the session redesign exactly **one loop** drives it: the
//! session loop of [`crate::serving::session`], which adds command
//! intake (submit / cancel / snapshot), [`Priority`]-tiered admission,
//! and per-request token streaming on top.  Every serving entry point
//! is an admission script over that loop — [`scheduler::serve`]
//! (everything submitted up front at one stamp, bit-identical to the
//! pre-redesign closed loop), [`crate::serving::serve_open_loop`]
//! (arrival-stamped trace release, virtual-clock determinism, recompute
//! preemption), [`crate::serving::sweep()`] (rate-rescaled open-loop
//! runs), and the live long-lived [`crate::serving::AmlaEngine`]
//! session — so the paths cannot drift apart in token accounting or
//! page lifecycle.
//!
//! Python never appears here — the executables were AOT-compiled by
//! `make artifacts`.  The stack is generic over [`engine::LayerExecutor`]
//! so integration tests can run the identical coordinator against the
//! bit-exact Rust numerics instead of PJRT (mock-substrate testing), and
//! the std-thread scheduler stands in for the unavailable tokio runtime
//! (offline build; see Cargo.toml note).

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod workload;

pub use batcher::{Batcher, BatcherStats, ElasticPolicy, ShedBatch,
                  ShedPolicy};
pub use engine::{DecodeEngine, HostLayerExecutor, LayerExecutor,
                 PjrtLayerExecutor, StepJob, StepTrace};
pub use metrics::Metrics;
pub use request::{DecodeRequest, DecodeResult, Outcome, Priority,
                  RequestId, RequestState};
pub use scheduler::{serve, ServeReport, StepCore};
pub use workload::{follow_up_request, generate_trace, long_context_spec,
                   requests_of, ArrivalProcess, ConversationSpec, LenDist,
                   TracedRequest, WorkloadSpec, LONG_CONTEXT_TOKENS};
