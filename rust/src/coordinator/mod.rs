//! L3 decode-serving coordinator.
//!
//! The serving shape of the paper's contribution: AMLA is a decode
//! kernel, so the coordinator is a vLLM-style decode loop with the
//! kernel as its hot path:
//!
//! ```text
//! requests → [batcher: admission + continuous batching]
//!          → [scheduler: worker threads, one decode step per sequence]
//!          → [engine: N-layer MLA model over PJRT layer executables]
//!          → [kvcache: paged latent pool, bucket materialization]
//!          → streamed tokens + metrics
//! ```
//!
//! Python never appears here — the executables were AOT-compiled by
//! `make artifacts`.  The stack is generic over [`engine::LayerExecutor`]
//! so integration tests can run the identical coordinator against the
//! bit-exact Rust numerics instead of PJRT (mock-substrate testing), and
//! the std-thread scheduler stands in for the unavailable tokio runtime
//! (offline build; see Cargo.toml note).

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod workload;

pub use batcher::{Batcher, BatcherStats};
pub use engine::{DecodeEngine, HostLayerExecutor, LayerExecutor,
                 PjrtLayerExecutor};
pub use metrics::Metrics;
pub use request::{DecodeRequest, DecodeResult, RequestId, RequestState};
pub use scheduler::{serve, ServeReport};
pub use workload::{generate_trace, requests_of, LenDist, TracedRequest,
                   WorkloadSpec};
