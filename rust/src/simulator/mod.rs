//! Kernel performance simulator — regenerates Table 5 / Fig 10.
//!
//! The paper's silicon results cannot be measured here (repro band 0/5:
//! no Ascend 910, no H800), so this module *derives* kernel duration and
//! FLOPS utilization from the same first-principles models the paper
//! uses to design the kernel:
//!
//! * `[C1]`/`[C2]` durations from the hierarchical-tiling pipe simulation
//!   ([`crate::tiling::cube_pipe`]) under the Da Vinci memory system;
//! * `[V1]` (and, for Base, `[V2]`) durations from vector-core throughput
//!   and UB↔GM traffic;
//! * stage composition through the Preload Pipeline timeline simulator
//!   ([`crate::pipeline::schedule`]) — AMLA as the `n = 2, V2 = 0`
//!   instance, Base as the 4-stage chain with the GM↔UB rescale;
//! * a FlashMLA-style model for the H800-class comparator
//!   ([`flashmla`]): BLOCK_SIZE_M = 64 row-blocks with KV re-reads
//!   partially absorbed by L2, seesaw tensor/CUDA-core overlap.
//!
//! Absolute microseconds are a model, not silicon; what must (and does —
//! see EXPERIMENTS.md E4) reproduce is the *shape*: FU monotone in S_k,
//! MTP (S_q = 2) above S_q = 1, AMLA-on-910 above FlashMLA-on-GPU, the
//! headline ≈ 86.8 % at (S_q = 2, S_k = 16384), and Base-on-910 far below
//! AMLA (the ablation the paper implies in §3.3).

pub mod ascend;
pub mod flashmla;
pub mod table5;

pub use ascend::{simulate_ascend, AscendKernelModel};
pub use flashmla::{simulate_flashmla, FlashMlaModel};
pub use table5::{table5_rows, Table5Row, PAPER_TABLE5};

use crate::config::Algo;

/// One simulated kernel invocation's workload.
#[derive(Debug, Clone, Copy)]
pub struct KernelConfig {
    /// Sequences in the batch (paper: 96).
    pub batch: usize,
    /// Query heads (paper: 128).
    pub n1: usize,
    /// Query positions (1 = decode, 2 = MTP).
    pub sq: usize,
    /// KV context length.
    pub sk: usize,
    /// KV rows per FlashAttention iteration (paper: 512).
    pub block_kv: usize,
}

impl KernelConfig {
    pub fn paper(sq: usize, sk: usize) -> Self {
        Self { batch: 96, n1: 128, sq, sk, block_kv: 512 }
    }

    /// Total attention FLOPs across the batch (§2.4).
    pub fn flops(&self) -> f64 {
        2.0 * self.batch as f64 * self.n1 as f64 * self.sq as f64
            * self.sk as f64 * (576 + 512) as f64
    }

    /// Query rows per sequence (M of the tiling analysis).
    pub fn m(&self) -> usize {
        self.n1 * self.sq
    }

    /// FlashAttention iterations per sequence.
    pub fn iterations(&self) -> usize {
        self.sk.div_ceil(self.block_kv)
    }
}

/// Simulated kernel outcome.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub duration_us: f64,
    /// FLOPS utilization vs the device peak.
    pub fu: f64,
    pub flops: f64,
    /// Human-readable description of the binding resource.
    pub bound_by: String,
}

/// Convenience: simulate `algo` on the Ascend 910 model.
pub fn simulate_910(cfg: &KernelConfig, algo: Algo) -> SimResult {
    simulate_ascend(&AscendKernelModel::default(), cfg, algo)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_formula_matches_paper_example() {
        // Sq=2, Sk=16384: 2*96*128*2*16384*1088 = 876.2 GFLOP... at
        // 614 TFLOPS ≈ 1427 us (Table 5's headline row)
        let cfg = KernelConfig::paper(2, 16384);
        let t_us = cfg.flops() / 614e12 * 1e6;
        assert!((t_us - 1427.0).abs() / 1427.0 < 0.01, "{t_us}");
    }

    #[test]
    fn m_and_iterations() {
        let cfg = KernelConfig::paper(2, 3072);
        assert_eq!(cfg.m(), 256);
        assert_eq!(cfg.iterations(), 6);
    }
}
