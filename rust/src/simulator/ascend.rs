//! AMLA / Base kernel timing on the Ascend 910 model.
//!
//! Steady-state timing follows the bottleneck law over *aggregated*
//! pipes: with the §4.2 triple-buffered L1 and identical `[C1]`/`[C2]`
//! tilings (Remark 4.1), MTE2 prefetch runs continuously across stage
//! boundaries, so one FlashAttention iteration costs
//!
//! ```text
//! per_iter = max( Σ MMAD,  Σ MTE2_effective,  Σ MTE1,  Σ FixP,  Σ V )
//!            + stage-sync overhead
//! ```
//!
//! where `Σ V` is the vector-stage work the Preload Pipeline must hide
//! (AMLA: `[V1]` only; Base: `[V1] + [V2]` with the GM↔UB round trip of
//! the FP32 output tile).  Three variants are modelled:
//!
//! * [`AscendVariant::Amla`] — the paper's kernel: 3-stage chain, `[V2]`
//!   eliminated, Preload Pipeline hides `[V1]`.
//! * [`AscendVariant::BasePipelined`] — ablation: keep the Preload
//!   Pipeline but keep `[V2]` too; the resident O tile contends for UB
//!   (§3.1), halving effective UB bandwidth, and the longer V-chain can
//!   flip the kernel vector-bound.
//! * [`AscendVariant::BaseSerialized`] — the pre-AMLA status quo the
//!   introduction describes ("current kernels serialize Cube and Vector
//!   operations"): stages run back-to-back.
//!
//! Calibration protocol: `launch_overhead` and `stage_sync` are fitted
//! once against the (S_q=1, S_k=1024) row of Table 5; every other cell
//! is then *predicted* (tests require ≤ 8 FU points absolute error,
//! mean ≤ 4).

use crate::config::Algo;
use crate::hardware::Ascend910;
use crate::tiling::{simulate_cube_stage, PipeRates, StageDims, TileSpec};

use super::{KernelConfig, SimResult};

/// Which Ascend kernel implementation to simulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AscendVariant {
    Amla,
    BasePipelined,
    BaseSerialized,
}

/// Tunable constants of the Ascend kernel model (see module docs for the
/// calibration protocol).
#[derive(Debug, Clone, Copy)]
pub struct AscendKernelModel {
    pub hw: Ascend910,
    /// Kernel launch + argument staging + epilogue (s).
    pub launch_overhead: f64,
    /// Per-cube-stage synchronization bubble (scalar pipeline barriers,
    /// L0C drain before reuse) — exposed even in steady state (s).
    pub stage_sync: f64,
    /// Vector-core elementwise throughput per core (FLOP/s, FP32).
    pub vector_core_flops: f64,
    /// UB↔GM bandwidth per Vector core (bytes/s).
    pub ub_gm_bw: f64,
    /// Vector ops per score element in [V1] (max/exp/sum/scale + AMLA's
    /// fused exponent bookkeeping, Remark 3.2).
    pub v1_ops_per_elem: f64,
    /// L2 speedup for the second read of the shared latent (V reuses
    /// K's latent columns; §4.2 "served from L2 Cache").
    pub l2_reuse_factor: f64,
}

impl Default for AscendKernelModel {
    fn default() -> Self {
        Self {
            hw: Ascend910::default(),
            launch_overhead: 30e-6,
            stage_sync: 1.0e-6,
            vector_core_flops: 250e9,
            ub_gm_bw: 100e9,
            v1_ops_per_elem: 8.0,
            l2_reuse_factor: 4.0,
        }
    }
}

/// Aggregated per-iteration pipe totals (seconds, one Cube core + its
/// two Vector cores).
#[derive(Debug, Clone, Copy)]
pub struct IterPipes {
    pub mmad: f64,
    pub mte2: f64,
    pub mte1: f64,
    pub fixp: f64,
    pub v1: f64,
    pub v2: f64,
}

impl AscendKernelModel {
    /// Pipe totals for one FlashAttention iteration at M query rows.
    pub fn iteration_pipes(&self, m: usize, block_kv: usize,
                           ub_contention: f64) -> IterPipes {
        let rates = PipeRates::ascend910_per_core();
        let c1 = simulate_cube_stage(&StageDims::c1(m),
                                     &TileSpec::paper_c1(), &rates);
        let c2 = simulate_cube_stage(&StageDims::c2(m),
                                     &TileSpec::paper_c2(), &rates);
        // MTE2: K block from HBM; V re-reads the shared latent via L2.
        let mte2 = c1.mte2 + c2.mte2 / self.l2_reuse_factor;

        // [V1]: online softmax on M x block_kv scores across 2 Vector
        // cores, plus the S/P tiles crossing GM (Cube<->Vector exchange).
        let elems = (m * block_kv) as f64;
        let ub_bw = 2.0 * self.ub_gm_bw * ub_contention;
        let v1 = elems * self.v1_ops_per_elem / (2.0 * self.vector_core_flops)
            + (elems * 4.0 + elems * 2.0) / ub_bw;

        // [V2] (Base only): O tile GM->UB, rescale FMA, UB->GM + T read.
        let o_bytes = (m * 512 * 4) as f64;
        let v2 = 3.0 * o_bytes / ub_bw
            + (m * 512) as f64 * 2.0 / (2.0 * self.vector_core_flops);

        IterPipes { mmad: c1.mmad + c2.mmad, mte2, mte1: c1.mte1 + c2.mte1,
                    fixp: c1.fixp + c2.fixp, v1, v2 }
    }

    /// Steady-state per-iteration duration for a variant.
    pub fn per_iteration(&self, m: usize, block_kv: usize,
                         variant: AscendVariant) -> f64 {
        match variant {
            AscendVariant::Amla => {
                let p = self.iteration_pipes(m, block_kv, 1.0);
                p.mmad.max(p.mte2).max(p.mte1).max(p.fixp).max(p.v1)
                    + 2.0 * self.stage_sync
            }
            AscendVariant::BasePipelined => {
                // resident O tile contends UB (§3.1): half bandwidth
                let p = self.iteration_pipes(m, block_kv, 0.5);
                p.mmad.max(p.mte2).max(p.mte1).max(p.fixp).max(p.v1 + p.v2)
                    + 2.0 * self.stage_sync
            }
            AscendVariant::BaseSerialized => {
                let p = self.iteration_pipes(m, block_kv, 1.0);
                // stages back-to-back: cube pipes overlap within a stage
                // but V stages are exposed
                p.mmad.max(p.mte2).max(p.mte1).max(p.fixp) + p.v1 + p.v2
                    + 2.0 * self.stage_sync
            }
        }
    }
}

/// Simulate one kernel invocation on the 910 model.
pub fn simulate_ascend_variant(model: &AscendKernelModel,
                               cfg: &KernelConfig,
                               variant: AscendVariant) -> SimResult {
    let m = cfg.m();
    let cores = model.hw.cube_cores();
    let seqs_per_core = cfg.batch.div_ceil(cores);
    let iterations = cfg.iterations() * seqs_per_core;

    let per_iter = model.per_iteration(m, cfg.block_kv, variant);
    // Preload warm-up ~ one extra iteration (Preload count = n = 2
    // stages of C1-size work); serialized has no warm-up but no overlap.
    let warmup = match variant {
        AscendVariant::BaseSerialized => 0.0,
        _ => per_iter,
    };
    let duration = model.launch_overhead + warmup
        + iterations as f64 * per_iter;

    let flops = cfg.flops();
    let fu = flops / (duration * model.hw.peak_bf16_flops);
    let p = model.iteration_pipes(m, cfg.block_kv, 1.0);
    let vtot = match variant {
        AscendVariant::Amla => p.v1,
        _ => p.v1 + p.v2,
    };
    let bound_by = if vtot > p.mmad.max(p.mte2) {
        "Vector".to_string()
    } else if p.mte2 > p.mmad {
        "Cube (MTE2)".to_string()
    } else {
        "Cube (MMAD)".to_string()
    };
    SimResult { duration_us: duration * 1e6, fu, flops, bound_by }
}

/// Simulate with the paper's two named algorithms (Base = serialized,
/// the status-quo kernel the introduction measures AMLA against).
pub fn simulate_ascend(model: &AscendKernelModel, cfg: &KernelConfig,
                       algo: Algo) -> SimResult {
    let variant = match algo {
        Algo::Amla => AscendVariant::Amla,
        Algo::Base => AscendVariant::BaseSerialized,
    };
    simulate_ascend_variant(model, cfg, variant)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(sq: usize, sk: usize, v: AscendVariant) -> SimResult {
        simulate_ascend_variant(&AscendKernelModel::default(),
                                &KernelConfig::paper(sq, sk), v)
    }

    #[test]
    fn fu_monotone_in_context_length() {
        for sq in [1, 2] {
            let mut prev = 0.0;
            for sk in [1024, 2048, 4096, 8192, 16384] {
                let r = sim(sq, sk, AscendVariant::Amla);
                assert!(r.fu > prev, "sq={sq} sk={sk}: {} !> {prev}", r.fu);
                prev = r.fu;
            }
        }
    }

    #[test]
    fn mtp_has_higher_utilization() {
        for sk in [1024, 4096, 16384] {
            let r1 = sim(1, sk, AscendVariant::Amla);
            let r2 = sim(2, sk, AscendVariant::Amla);
            assert!(r2.fu > r1.fu, "sk={sk}: {} !> {}", r2.fu, r1.fu);
        }
    }

    #[test]
    fn headline_fu_near_paper() {
        // paper: 86.8 % at Sq=2, Sk=16384
        let r = sim(2, 16384, AscendVariant::Amla);
        assert!((r.fu - 0.868).abs() < 0.04,
                "headline FU {:.3} vs paper 0.868", r.fu);
    }

    #[test]
    fn calibration_row_matches() {
        // paper: 40.9 % / 95 us at Sq=1, Sk=1024 (the fitted row)
        let r = sim(1, 1024, AscendVariant::Amla);
        assert!((r.fu - 0.409).abs() < 0.03,
                "short FU {:.3} vs paper 0.409", r.fu);
        assert!((r.duration_us - 95.0).abs() < 10.0, "{}", r.duration_us);
    }

    #[test]
    fn ablation_ordering_amla_gt_pipelined_gt_serialized() {
        for (sq, sk) in [(1, 4096), (2, 4096), (2, 16384)] {
            let a = sim(sq, sk, AscendVariant::Amla);
            let bp = sim(sq, sk, AscendVariant::BasePipelined);
            let bs = sim(sq, sk, AscendVariant::BaseSerialized);
            assert!(a.fu >= bp.fu - 1e-9, "sq={sq} sk={sk}");
            assert!(bp.fu > bs.fu, "sq={sq} sk={sk}: {} !> {}", bp.fu, bs.fu);
            assert!(bs.duration_us > a.duration_us * 1.15,
                    "sq={sq} sk={sk}: serialized {} vs amla {}",
                    bs.duration_us, a.duration_us);
        }
    }

    #[test]
    fn amla_v1_is_hidden() {
        let m = AscendKernelModel::default();
        let p = m.iteration_pipes(256, 512, 1.0);
        assert!(p.v1 < p.mmad, "V1 {} must hide under MMAD {}", p.v1, p.mmad);
    }

    #[test]
    fn base_pipelined_goes_vector_bound_at_mtp() {
        // the §3.1 motivation: with [V2] present and UB contention, the
        // V-chain exceeds the cube time at M=256
        let m = AscendKernelModel::default();
        let p = m.iteration_pipes(256, 512, 0.5);
        assert!(p.v1 + p.v2 > p.mmad,
                "v {} vs mmad {}", p.v1 + p.v2, p.mmad);
    }

    #[test]
    fn amla_is_cube_bound() {
        let r = sim(2, 8192, AscendVariant::Amla);
        assert!(r.bound_by.starts_with("Cube"), "{}", r.bound_by);
    }
}
