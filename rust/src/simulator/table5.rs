//! Table 5 / Fig 10 sweep driver, plus the paper's published numbers for
//! side-by-side comparison in reports and tests.

use super::{simulate_910, simulate_flashmla, FlashMlaModel, KernelConfig,
            SimResult};
use crate::config::Algo;

/// The paper's Table 5 (duration µs, FU) — `(sq, sk, hw, dur_us, fu)`.
pub const PAPER_TABLE5: &[(usize, usize, &str, f64, f64)] = &[
    (1, 1024, "910", 95.0, 0.409),
    (1, 1024, "GPU", 85.0, 0.326),
    (1, 2048, "910", 140.0, 0.551),
    (1, 2048, "GPU", 128.0, 0.433),
    (1, 3072, "910", 186.0, 0.624),
    (1, 3072, "GPU", 173.0, 0.480),
    (1, 4096, "910", 241.0, 0.641),
    (1, 4096, "GPU", 215.0, 0.515),
    (1, 6144, "910", 331.0, 0.702),
    (1, 6144, "GPU", 316.0, 0.526),
    (1, 16384, "910", 830.0, 0.745),
    (1, 16384, "GPU", 766.0, 0.578),
    (2, 1024, "910", 135.0, 0.573),
    (2, 1024, "GPU", 115.0, 0.481),
    (2, 2048, "910", 219.0, 0.707),
    (2, 2048, "GPU", 196.0, 0.565),
    (2, 3072, "910", 306.0, 0.758),
    (2, 3072, "GPU", 278.0, 0.598),
    (2, 4096, "910", 388.0, 0.797),
    (2, 4096, "GPU", 374.0, 0.592),
    (2, 6144, "910", 565.0, 0.822),
    (2, 6144, "GPU", 527.0, 0.630),
    (2, 16384, "910", 1427.0, 0.868),
    (2, 16384, "GPU", 1314.0, 0.674),
];

/// One regenerated row next to the paper's.
#[derive(Debug, Clone)]
pub struct Table5Row {
    pub sq: usize,
    pub sk: usize,
    pub hw: &'static str,
    pub sim: SimResult,
    pub paper_duration_us: f64,
    pub paper_fu: f64,
}

impl Table5Row {
    pub fn fu_abs_err(&self) -> f64 {
        (self.sim.fu - self.paper_fu).abs()
    }
}

/// Regenerate every Table 5 cell from the simulators.
pub fn table5_rows() -> Vec<Table5Row> {
    PAPER_TABLE5
        .iter()
        .map(|&(sq, sk, hw, dur, fu)| {
            let cfg = KernelConfig::paper(sq, sk);
            let sim = match hw {
                "910" => simulate_910(&cfg, Algo::Amla),
                _ => simulate_flashmla(&FlashMlaModel::default(), &cfg),
            };
            Table5Row { sq, sk, hw, sim, paper_duration_us: dur,
                        paper_fu: fu }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_cell_within_tolerance() {
        // The shape requirement of DESIGN.md E4: each FU within 8 points
        // absolute of the paper (durations follow from FU by identity).
        for row in table5_rows() {
            assert!(row.fu_abs_err() < 0.08,
                    "sq={} sk={} {}: sim {:.3} vs paper {:.3}",
                    row.sq, row.sk, row.hw, row.sim.fu, row.paper_fu);
        }
    }

    #[test]
    fn ascend_beats_gpu_fu_everywhere() {
        let rows = table5_rows();
        for sq in [1, 2] {
            for sk in [1024, 2048, 3072, 4096, 6144, 16384] {
                let f = |hw: &str| {
                    rows.iter()
                        .find(|r| r.sq == sq && r.sk == sk && r.hw == hw)
                        .unwrap()
                        .sim
                        .fu
                };
                assert!(f("910") > f("GPU"), "sq={sq} sk={sk}");
            }
        }
    }

    #[test]
    fn mean_fu_error_small() {
        let rows = table5_rows();
        let mean: f64 = rows.iter().map(|r| r.fu_abs_err()).sum::<f64>()
            / rows.len() as f64;
        assert!(mean < 0.04, "mean |ΔFU| = {mean:.4}");
    }
}
