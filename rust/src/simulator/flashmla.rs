//! FlashMLA-on-H800 comparator model (§2.5, the "GPU" rows of Table 5).
//!
//! FlashMLA processes the output in BLOCK_SIZE_M = 64 row blocks so that
//! rescaling can overlap with tensor-core work inside the 256 KB register
//! file ("seesaw" scheduling).  Consequences modelled here:
//!
//! * the KV stream is traversed once per 64-row block
//!   (`ceil(M/64)` passes); L2 absorbs most of the repeats
//!   (`l2_hit_rate`), the misses pay HBM bandwidth — this is the
//!   "additional overhead due to the repetitive movement … of KVCache"
//!   the paper attributes to FlashMLA;
//! * tensor-core efficiency is capped by the seesaw overlap
//!   (`overlap_efficiency`, the paper's footnote: 66.7 % of peak is
//!   80 % of the throttled peak);
//! * a fixed launch overhead, calibrated on the shortest row and held
//!   constant (same protocol as the Ascend model).

use super::{KernelConfig, SimResult};
use crate::hardware::GpuModel;

/// Tunables of the FlashMLA model.
#[derive(Debug, Clone, Copy)]
pub struct FlashMlaModel {
    pub hw: GpuModel,
    pub launch_overhead: f64,
    /// Fraction of repeat KV reads served by L2 instead of HBM.
    pub l2_hit_rate: f64,
    /// Peak tensor-core efficiency under the seesaw schedule.
    pub overlap_efficiency: f64,
}

impl Default for FlashMlaModel {
    fn default() -> Self {
        Self {
            hw: GpuModel::default(),
            launch_overhead: 30e-6,
            l2_hit_rate: 0.58,
            overlap_efficiency: 0.68,
        }
    }
}

/// Simulate one FlashMLA decode kernel on the GPU model.
pub fn simulate_flashmla(model: &FlashMlaModel, cfg: &KernelConfig)
                         -> SimResult {
    let flops = cfg.flops();
    let compute_time =
        flops / (model.hw.peak_bf16_flops * model.overlap_efficiency);

    // KV bytes: latent+rope (576 cols BF16) per token per sequence
    let kv_bytes =
        (cfg.batch * cfg.sk * 576 * 2) as f64;
    let row_blocks = cfg.m().div_ceil(model.hw.flashmla_block_m) as f64;
    // first pass from HBM; repeats mostly from L2
    let effective_bytes = kv_bytes
        * (1.0 + (row_blocks - 1.0) * (1.0 - model.l2_hit_rate));
    let memory_time = effective_bytes / model.hw.hbm_bandwidth;

    let duration =
        compute_time.max(memory_time) + model.launch_overhead;
    let fu = flops / (duration * model.hw.peak_bf16_flops);
    let bound_by = if memory_time > compute_time {
        format!("HBM ({} row-block passes)", row_blocks as usize)
    } else {
        "TensorCore (seesaw-capped)".to_string()
    };
    SimResult { duration_us: duration * 1e6, fu, flops, bound_by }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(sq: usize, sk: usize) -> SimResult {
        simulate_flashmla(&FlashMlaModel::default(),
                          &KernelConfig::paper(sq, sk))
    }

    #[test]
    fn fu_monotone_in_sk() {
        for sq in [1, 2] {
            let mut prev = 0.0;
            for sk in [1024, 2048, 4096, 16384] {
                let r = sim(sq, sk);
                assert!(r.fu > prev);
                prev = r.fu;
            }
        }
    }

    #[test]
    fn fu_ceiling_below_ascend_headline() {
        // paper: FlashMLA tops out at 67.4 % (Sq=2, Sk=16384)
        let r = sim(2, 16384);
        assert!((r.fu - 0.674).abs() < 0.06, "GPU headline {:.3}", r.fu);
        assert!(r.fu < 0.75);
    }

    #[test]
    fn short_row_near_paper() {
        // paper: 32.6 % at Sq=1, Sk=1024 (calibration row)
        let r = sim(1, 1024);
        assert!((r.fu - 0.326).abs() < 0.05, "{:.3}", r.fu);
    }

    #[test]
    fn sq1_is_memory_bound_sq2_less_so() {
        let r1 = sim(1, 8192);
        assert!(r1.bound_by.starts_with("HBM"), "{}", r1.bound_by);
    }
}
