//! E5 regenerator: preload-pipeline schedules (Figs 5–7) and the cost of
//! schedule construction/simulation (it runs inside the kernel
//! simulator's inner loop, so it must stay cheap).

use amla::bench_util::{bb, Bench};
use amla::pipeline::{simulate, CvChain, PipelineSchedule};
use amla::report;

fn main() {
    println!("{}", report::render_pipeline_demo());

    // Fig 5/6-style comparison across chain sizes
    println!("makespan: serialized vs preload (32 iterations):");
    for n in [2usize, 3, 4, 6] {
        let c: Vec<f64> = (0..n).map(|i| 8.0 + i as f64).collect();
        let v: Vec<f64> = (0..n).map(|i| 2.0 + 0.3 * i as f64).collect();
        let ch = CvChain::new(c, v);
        let ser = simulate(&ch, &PipelineSchedule::serialized(&ch, 32));
        let p = ch.optimal_rotation();
        let pre = simulate(&ch, &PipelineSchedule::preload(&ch, p, 32));
        println!("  n={n}: serialized {:8.1}  preload {:8.1}  speedup \
                  {:.2}x  (preload count {})",
                 ser.makespan, pre.makespan, ser.makespan / pre.makespan,
                 PipelineSchedule::preload(&ch, p, 32).preload_count);
    }

    let mut b = Bench::new("pipeline");
    let amla_chain = CvChain::amla_instance(10.0, 4.0, 9.0);
    b.bench("optimal_rotation/n2", || {
        bb(&amla_chain).optimal_rotation()
    });
    let big: CvChain = CvChain::new((0..16).map(|i| 5.0 + i as f64).collect(),
                                    (0..16).map(|i| 1.0 + i as f64 * 0.1).collect());
    b.bench("optimal_rotation/n16", || bb(&big).optimal_rotation());
    b.bench("build_schedule/n2_iters256", || {
        PipelineSchedule::preload(bb(&amla_chain), 1, 256)
    });
    let sched = PipelineSchedule::preload(&amla_chain, 1, 256);
    b.bench("simulate/n2_iters256", || simulate(bb(&amla_chain), bb(&sched)));
    b.finish();
}
