//! E1 regenerator: Table 2 + Fig 1 (arithmetic intensity / rooflines).

use amla::bench_util::{bb, Bench};
use amla::hardware::{Ascend910, GpuModel};
use amla::report;
use amla::roofline::{roofline_curve, roofline_points, AttentionVariant};

fn main() {
    println!("{}", report::render_table2());
    println!("{}", report::render_fig1_both());

    // Fig 1 curve data (for external plotting)
    let acc = Ascend910::accelerator();
    println!("roofline curve (Ascend 910), intensity -> TFLOPS:");
    for (x, y) in roofline_curve(&acc, 16) {
        println!("  {x:8.2} -> {:7.1}", y / 1e12);
    }

    let mut b = Bench::new("roofline");
    b.bench("points_910", || roofline_points(&bb(Ascend910::accelerator())));
    b.bench("points_gpu", || roofline_points(&bb(GpuModel::accelerator())));
    b.bench("table2_intensities", || {
        AttentionVariant::table2()
            .iter()
            .map(|v| v.intensity())
            .sum::<f64>()
    });
    b.finish();
}
