//! E4/E7 regenerator: Table 5 + Fig 10 from the performance simulators,
//! plus (when artifacts exist) measured wall times of the actual
//! CPU-PJRT kernels — the "our testbed" numbers EXPERIMENTS.md records
//! alongside the simulated Ascend/GPU cells.

use amla::bench_util::{bb, Bench};
use amla::numerics::Rng;
use amla::report;
use amla::runtime::{Engine, TensorView};
use amla::simulator::{simulate_910, KernelConfig};
use amla::config::Algo;

fn main() {
    println!("{}", report::render_table5());
    println!("{}", report::render_fig10());

    let mut b = Bench::new("table5");
    // simulator throughput itself (it sits on the coordinator's planning
    // path, so it must be cheap)
    b.bench("simulate_910/sq2_sk16384", || {
        simulate_910(&KernelConfig::paper(2, 16384), bb(Algo::Amla))
    });

    // measured CPU-PJRT kernel wall times per bucket (real execution of
    // the AOT artifacts; absolute numbers are CPU-bound, the *ratio*
    // AMLA:Base is the claim)
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let engine = Engine::new("artifacts").expect("engine");
        let mut rng = Rng::new(3);
        for bucket in [256usize, 512, 1024, 2048] {
            if engine.registry().kernel_buckets("amla", 16, 1)
                .iter().all(|&x| x != bucket) {
                continue;
            }
            let q = rng.gaussian_matrix(16, 576, 1.0);
            let k = rng.gaussian_matrix(bucket, 576, 1.0);
            let v = rng.gaussian_matrix(bucket, 512, 1.0);
            let valid = [bucket as i32];
            for algo in ["amla", "base"] {
                let kernel =
                    engine.load_kernel_for(algo, 16, 1, bucket).unwrap();
                b.bench(&format!("pjrt_{algo}/kv{bucket}"), || {
                    kernel
                        .run(&[
                            TensorView::F32(&q.data, &[16, 576]),
                            TensorView::F32(&k.data, &[bucket, 576]),
                            TensorView::F32(&v.data, &[bucket, 512]),
                            TensorView::I32(&valid, &[1]),
                        ])
                        .unwrap()
                });
            }
        }
    } else {
        eprintln!("artifacts/ missing — skipping measured-PJRT section");
    }
    b.finish();
}
