//! E8 regenerator: the design-choice ablations DESIGN.md calls out.
//!
//! 1. Kernel variant ablation on the 910 model: AMLA vs Base+pipeline
//!    (keeps [V2], keeps the preload pipeline) vs Base serialized — how
//!    much of the win is the MUL-by-ADD elimination vs the pipeline.
//! 2. Tiling ablation: the §4.2 balanced tiling vs the max-MMAD-only
//!    objective vs a deliberately small baseK.
//! 3. Numerics ablation: error compensation on/off at BF16 (App. A).

use amla::bench_util::{bb, Bench};
use amla::hardware::Ascend910;
use amla::numerics::bf16::bf16_round_slice;
use amla::numerics::flash_base::FlashConfig;
use amla::numerics::golden::golden_full;
use amla::numerics::{rel_frobenius_error, Rng};
use amla::report;
use amla::simulator::ascend::{simulate_ascend_variant, AscendKernelModel,
                              AscendVariant};
use amla::simulator::KernelConfig;
use amla::tiling::{simulate_cube_stage, solve_tiling, PipeRates, StageDims,
                   TileSpec, TilingObjective};

fn main() {
    println!("=== kernel variant ablation (910 model) ===");
    println!("{}", report::render_ablation());

    println!("=== tiling ablation ([C1], M=256) ===");
    let rates = PipeRates::ascend910_per_core();
    let mem = Ascend910::default().cube_mem;
    let candidates = [
        ("paper (balanced)", TileSpec::paper_c1()),
        ("solver MaxMmad",
         solve_tiling(&StageDims::c1(256), &mem, 128,
                      TilingObjective::MaxMmad)[0]),
        ("small baseK=32", TileSpec { base_k: 32, ..TileSpec::paper_c1() }),
    ];
    for (name, spec) in candidates {
        let t = simulate_cube_stage(&StageDims::c1(256), &spec, &rates);
        println!("  {name:<18} base {}x{}x{}: duration {:7.2} µs, \
                  MMAD duty {:.0}%, bound {}",
                 spec.base_m, spec.base_n, spec.base_k, t.duration * 1e6,
                 t.mmad_duty() * 100.0, t.bottleneck());
    }

    println!("\n=== error compensation ablation (Appendix A) ===");
    // Rust recurrence: compensation is always on in amla_attention; show
    // its effect via the Pallas-equivalent experiment recorded in
    // EXPERIMENTS.md (pytest test_error_compensation_helps) and pin here
    // the BF16-input error level with and without BF16 P·V.
    let mut rng = Rng::new(5);
    let mut q = rng.gaussian_matrix(16, 576, 1.0);
    let mut k = rng.gaussian_matrix(1024, 576, 1.0);
    let mut v = rng.gaussian_matrix(1024, 512, 1.0);
    bf16_round_slice(&mut q.data);
    bf16_round_slice(&mut k.data);
    bf16_round_slice(&mut v.data);
    let gold = golden_full(&q, &k, &v);
    for (name, bf) in [("fp32 matmuls", false), ("bf16 matmuls", true)] {
        let cfg = FlashConfig { block_kv: 512, n1: 16, sq: 1,
                                valid_len: 1024, mixed_bf16: bf };
        let a = amla::numerics::amla::amla_attention(&q, &k, &v, &cfg);
        println!("  AMLA {name}: rel err {:.2e}",
                 rel_frobenius_error(&a.data, &gold.data));
    }

    let mut b = Bench::new("ablation");
    let model = AscendKernelModel::default();
    for (name, variant) in [("amla", AscendVariant::Amla),
                            ("base_pipelined", AscendVariant::BasePipelined),
                            ("base_serialized", AscendVariant::BaseSerialized)] {
        b.bench(&format!("sim_{name}/sq2_sk16384"), || {
            simulate_ascend_variant(&model, &KernelConfig::paper(2, 16384),
                                    bb(variant))
        });
    }
    b.finish();
}
