//! Open-loop serving benchmarks: the rate-sweep SLO harness on the host
//! substrate under the deterministic virtual clock.
//!
//! Produces the offered-rate → TTFT/TPOT/queue-delay load curve plus
//! the saturation throughput estimate — the serving-side number the
//! AMLA kernel's batched throughput ultimately feeds.  Deterministic
//! (virtual clock, seeded trace), so it doubles as the CI bench-smoke
//! target: `AMLA_BENCH_SMOKE=1` shrinks it to 2 rates × 8 requests.
//!
//! `AMLA_BENCH_RECORD=1` writes the sweep report to
//! `BENCH_serving.json` (committed placeholder at the repo root),
//! mirroring `BENCH_coordinator.json`.

use std::collections::BTreeMap;

use amla::config::{Algo, ServeConfig};
use amla::coordinator::engine::SeqRuntime;
use amla::coordinator::{follow_up_request, generate_trace,
                        long_context_spec, serve, ConversationSpec,
                        DecodeEngine, DecodeRequest, HostLayerExecutor,
                        LayerExecutor, LenDist, RequestId, TracedRequest,
                        WorkloadSpec, LONG_CONTEXT_TOKENS};
use amla::numerics::mla::MlaDims;
use amla::serving::clock::SimClock;
use amla::serving::{chaos_sweep, serve_open_loop, sweep, ChaosSweepConfig,
                    FlashCrowdSpec, StepCostModel, SweepConfig};
use amla::util::json::Json;

fn main() {
    let smoke = std::env::var("AMLA_BENCH_SMOKE").is_ok();
    let (n_requests, rates): (usize, Vec<f64>) = if smoke {
        (8, vec![2.0, 32.0])
    } else {
        (48, vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0])
    };

    let dims = MlaDims { d_model: 64, n1: 2, d_head: 16, q_rank: 32,
                         d_latent: 24, d_rope: 8, sq: 1 };
    let exec = HostLayerExecutor::new(dims, 2, Algo::Amla, 64,
                                      vec![64, 128], 3);
    let engine = DecodeEngine::new(exec, 512, 16);

    let spec = WorkloadSpec {
        requests: n_requests,
        rate: 4.0,
        prompt_len: LenDist::Uniform(3, 10),
        gen_len: LenDist::Geometric { mean: 12.0, cap: 40 },
        ..WorkloadSpec::default()
    };
    let trace = generate_trace(&spec);
    let cfg = ServeConfig { max_batch: 8, workers: 4, batch_workers: 4,
                            pool_pages: 512, page_size: 16,
                            starvation_steps: 16, preempt: true,
                            ..ServeConfig::default() };
    let sweep_cfg = SweepConfig {
        rates,
        saturation_fraction: 0.8,
        model: StepCostModel::new(2e-3, 5e-4),
    };

    // chunked-prefill contrast: the same trace served open-loop at the
    // legacy token-per-step prefill vs the default chunk.  Asserted:
    // identical tokens (the chunked-prefill bit-identity contract) and
    // strictly fewer prefill invocations.  Mean TTFT under the row-cost
    // virtual clock is printed for the record, not asserted — with
    // preemption on, eviction patterns may shift per-request TTFTs
    // either way even though prefill itself got cheaper.
    {
        let run = |chunk: usize| {
            let mut c = cfg.clone();
            c.prefill_chunk = chunk;
            let mut clock =
                SimClock::simulated(sweep_cfg.model.clone());
            serve_open_loop(&engine, trace.clone(), &c, &mut clock)
                .expect("open-loop chunk-contrast run failed")
        };
        let legacy = run(1);
        let chunked = run(cfg.prefill_chunk);
        let tokens = |r: &amla::serving::OpenLoopReport| {
            let mut t: Vec<_> = r.results.iter()
                .map(|x| (x.id, x.tokens.clone()))
                .collect();
            t.sort_by_key(|(id, _)| *id);
            t
        };
        assert_eq!(tokens(&legacy), tokens(&chunked),
                   "chunked prefill changed served tokens");
        assert!(chunked.metrics.prefill_chunks
                    < legacy.metrics.prefill_chunks,
                "chunking must cut prefill invocations ({} vs {})",
                chunked.metrics.prefill_chunks,
                legacy.metrics.prefill_chunks);
        let mean_ttft = |r: &amla::serving::OpenLoopReport| {
            let n = r.results.len().max(1);
            r.results.iter().map(|x| x.ttft).sum::<f64>() / n as f64
        };
        println!(
            "prefill chunk {}: {} prefill invocations for {} prompt \
             tokens (chunk 1: {}), mean TTFT {:.4}s (chunk 1: {:.4}s)",
            cfg.prefill_chunk, chunked.metrics.prefill_chunks,
            chunked.metrics.prompt_tokens,
            legacy.metrics.prefill_chunks,
            mean_ttft(&chunked), mean_ttft(&legacy));
    }

    // shared-prefix contrast: a 2-conversation x 3-turn follow-up
    // workload (each turn's prompt is the previous turn's transcript
    // plus fresh user tokens) served open-loop with the prefix cache
    // off vs on.  Asserted: bit-identical tokens, >= 1 hit, and
    // strictly fewer prefill invocations — the cache must be a pure
    // scheduling optimization.
    let prefix_cache = {
        let conv_engine = || {
            let exec = HostLayerExecutor::new(dims, 2, Algo::Amla, 64,
                                              vec![64, 128], 3);
            DecodeEngine::new(exec, 512, 16)
        };
        // fixed generation lengths so every follow-up's transcript
        // covers at least one whole 16-row physical page
        let cspec = ConversationSpec {
            gen_len: LenDist::Fixed(12),
            ..ConversationSpec::default()
        };
        let mut conv_trace = Vec::new();
        let mut id: RequestId = 0;
        for conv in 0..2u64 {
            let opening: Vec<u32> =
                (0..9).map(|i| 2000 * conv as u32 + 23 + i).collect();
            let mut req = DecodeRequest::new(id, opening, 12);
            for turn in 0..cspec.turns {
                conv_trace.push(TracedRequest {
                    request: req.clone(),
                    arrival: conv as f64 * 0.1 + turn as f64 * 1.0,
                });
                if turn + 1 == cspec.turns {
                    break;
                }
                let res = serve(&conv_engine(), vec![req.clone()], &cfg)
                    .expect("conversation oracle run failed");
                id += 1;
                req = follow_up_request(&cspec, conv, turn + 1, id,
                                        &req.prompt,
                                        &res.results[0].tokens);
            }
            id += 1;
        }
        let run = |prefix: bool| {
            let mut c = cfg.clone();
            c.prefix_cache = prefix;
            let mut clock = SimClock::simulated(sweep_cfg.model.clone());
            serve_open_loop(&conv_engine(), conv_trace.clone(), &c,
                            &mut clock)
                .expect("open-loop prefix-contrast run failed")
        };
        let off = run(false);
        let on = run(true);
        let tokens = |r: &amla::serving::OpenLoopReport| {
            let mut t: Vec<_> = r.results.iter()
                .map(|x| (x.id, x.tokens.clone()))
                .collect();
            t.sort_by_key(|(id, _)| *id);
            t
        };
        assert_eq!(tokens(&off), tokens(&on),
                   "prefix cache changed served tokens");
        assert_eq!(off.metrics.prefix_hits, 0,
                   "prefix off must never hit");
        assert!(on.metrics.prefix_hits >= 1,
                "conversational workload must hit the prefix cache");
        assert!(on.metrics.prefill_chunks < off.metrics.prefill_chunks,
                "prefix hits must cut prefill invocations ({} vs {})",
                on.metrics.prefill_chunks, off.metrics.prefill_chunks);
        println!("prefix cache: {} hits ({} shared rows) over {} turns, \
                  prefill invocations {} -> {}, prompt rows {} -> {}",
                 on.metrics.prefix_hits, on.metrics.prefix_hit_rows,
                 conv_trace.len(), off.metrics.prefill_chunks,
                 on.metrics.prefill_chunks, off.metrics.prompt_tokens,
                 on.metrics.prompt_tokens);
        (conv_trace.len(), on.metrics.prefix_hits,
         on.metrics.prefix_hit_rows, off.metrics.prefill_chunks,
         on.metrics.prefill_chunks)
    };

    println!("open-loop rate sweep ({n_requests} requests, virtual clock, \
              preempt on{}):", if smoke { ", SMOKE" } else { "" });
    let t0 = std::time::Instant::now();
    let report = sweep(&engine, &trace, spec.rate, &cfg, &sweep_cfg)
        .expect("sweep failed");
    println!("{}", report.render_table());
    // engine-level gauges of the hottest rate point (the session-API
    // metrics snapshot: per-class queue peaks, cancellations, streamed
    // tokens — streaming is zero here, the sweep attaches no clients)
    if let Some(point) = report.points.last() {
        let m = &point.metrics;
        println!("engine gauges @ {:.1} req/s offered: queue depth peak \
                  interactive/batch/background {}/{}/{}, cancelled {}, \
                  streamed tokens {}",
                 point.offered_rate,
                 m.queue_depth_peak[0], m.queue_depth_peak[1],
                 m.queue_depth_peak[2], m.requests_cancelled,
                 m.streamed_tokens);
        assert_eq!(m.requests_cancelled, 0,
                   "nothing cancels in a sweep");
        assert!(m.queue_depth_peak.iter().sum::<u64>() > 0,
                "a saturating sweep must have queued somewhere");
    }
    println!("(sweep wall time: {:.2?})", t0.elapsed());

    // smoke invariants: the harness must produce a well-formed,
    // saturation-capable report even at tiny scale
    assert_eq!(report.points.len(), sweep_cfg.rates.len());
    for w in report.points.windows(2) {
        assert!(w[1].offered_rate > w[0].offered_rate,
                "points must be rate-sorted");
    }
    assert!(report.saturation_throughput > 0.0);

    // preempt off for contrast (same trace, same rates)
    let mut cfg_off = cfg.clone();
    cfg_off.preempt = false;
    let report_off = sweep(&engine, &trace, spec.rate, &cfg_off, &sweep_cfg)
        .expect("sweep (preempt off) failed");
    println!("preempt off, highest rate: ttft p99 {:.3}s (vs {:.3}s with \
              preemption)",
             report_off.points.last().unwrap().ttft_p99,
             report.points.last().unwrap().ttft_p99);

    // long-context split-KV contrast: the 128k scenario from
    // `long_context_spec` — a single decoding sequence whose KV history
    // dwarfs the batch, so every batch worker but one would sit idle
    // unless the KV scan itself is partitioned.  The history is stood
    // up synthetically (`warm_synthetic_context`; prefilling 128k
    // tokens through the layers would dominate the bench), then the
    // same decode steps run single-pass vs split across 4 workers.
    // Asserted: bit-identical tokens (the split kernel's frame-replay
    // contract) and >1 partition per split call (the >1-worker
    // utilization the route exists for).
    let long_context = {
        let ctx = if smoke { 8192 } else { LONG_CONTEXT_TOKENS };
        let lc_spec = long_context_spec(1, ctx, 5);
        let gen = match lc_spec.gen_len {
            LenDist::Fixed(n) => n,
            _ => unreachable!("long-context generation length is fixed"),
        };
        let bucket = ctx + 128;
        let run = |workers: usize, threshold: usize| {
            let exec = HostLayerExecutor::new(dims, 2, Algo::Amla, 64,
                                              vec![64, bucket], 3)
                .with_split_kv(threshold);
            let engine = DecodeEngine::new(exec, bucket * 2 / 16 + 16, 16);
            let mut rt = SeqRuntime::new(2);
            engine.warm_synthetic_context(&mut rt, ctx, lc_spec.seed)
                .expect("synthetic long-context warm failed");
            let mut tokens = Vec::with_capacity(gen);
            let mut last = 7u32;
            for _ in 0..gen {
                last = engine
                    .step_batch(std::slice::from_mut(&mut rt), &[last],
                                workers)
                    .pop()
                    .expect("one result per sequence")
                    .expect("long-context decode step failed");
                tokens.push(last);
            }
            (tokens, engine.executor.split_stats()
                .expect("host executor exposes split counters"))
        };
        let t0 = std::time::Instant::now();
        let (tok_single, stats_single) = run(1, 0);
        let (tok_split, (calls, parts)) = run(4, 1024);
        assert_eq!(tok_single, tok_split,
                   "split-KV flash decoding changed long-context tokens");
        assert_eq!(stats_single, (0, 0), "single-worker run must not split");
        assert!(calls > 0, "long-context decode must route through split-KV");
        assert!(parts >= 2 * calls,
                "each split call must utilize >1 worker \
                 ({parts} partitions over {calls} calls)");
        println!("long-context scenario ({} KV rows{}): {} decode steps, \
                  {} split calls, mean {:.1} partitions/call, tokens \
                  bit-identical to the single-pass loop ({:.2?})",
                 ctx, if smoke { ", SMOKE" } else { "" }, gen, calls,
                 parts as f64 / calls as f64, t0.elapsed());
        (ctx, gen, calls, parts)
    };

    // survivable-envelope chaos sweep: the flash-crowd scenario (an
    // Interactive base load plus a Batch spike at each multiplier)
    // served with degrade shedding, priority aging, the prefix cache,
    // and split-KV enabled — the full elastic config, deterministic
    // under the virtual clock.  Asserted: the whole curve replays
    // byte-identically, and degrade never drops base traffic.
    let chaos = {
        let mults: Vec<f64> = if smoke {
            vec![1.0, 10.0]
        } else {
            vec![1.0, 2.0, 5.0, 10.0, 25.0, 50.0, 100.0]
        };
        let mut chaos_cfg = cfg.clone();
        chaos_cfg.shed_policy = amla::config::ShedPolicy::Degrade;
        chaos_cfg.shed_queue_depth = 16;
        chaos_cfg.age_steps = 32;
        chaos_cfg.prefix_cache = true;
        chaos_cfg.split_kv_threshold = 16;
        let base = FlashCrowdSpec {
            base_requests: if smoke { 8 } else { 16 },
            spike_requests: if smoke { 12 } else { 32 },
            ..FlashCrowdSpec::default()
        };
        let base_total = base.base_requests as u64;
        let ccfg = ChaosSweepConfig { multipliers: mults,
                                      slo_ttft_p99_s: 0.5,
                                      model: sweep_cfg.model.clone(),
                                      base };
        let t0 = std::time::Instant::now();
        let report = chaos_sweep(&engine, &chaos_cfg, &ccfg)
            .expect("chaos sweep failed");
        let replay = chaos_sweep(&engine, &chaos_cfg, &ccfg)
            .expect("chaos sweep replay failed");
        assert_eq!(report.to_json().to_string(),
                   replay.to_json().to_string(),
                   "chaos sweep must replay byte-identically");
        for p in &report.points {
            assert_eq!(p.base_completed, base_total,
                       "degrade shedding dropped base traffic at {}x",
                       p.multiplier);
            assert!(p.ttft_p99_interactive.is_finite());
        }
        println!("{}", report.render_table());
        println!("(chaos sweep wall time, both passes: {:.2?})",
                 t0.elapsed());
        report
    };

    // perf-trajectory baseline: BENCH_serving.json at the repo root
    // (opt-in so routine bench runs do not dirty the tree)
    if std::env::var("AMLA_BENCH_RECORD").is_ok() {
        let mut json = report.to_json();
        if let Json::Obj(ref mut root) = json {
            let (ctx, gen, calls, parts) = long_context;
            let mut lc = BTreeMap::new();
            lc.insert("context_rows".into(), Json::Num(ctx as f64));
            lc.insert("decode_steps".into(), Json::Num(gen as f64));
            lc.insert("split_calls".into(), Json::Num(calls as f64));
            lc.insert("split_partitions".into(), Json::Num(parts as f64));
            root.insert("long_context".into(), Json::Obj(lc));
            let (turns, hits, hit_rows, pc_off, pc_on) = prefix_cache;
            let mut pc = BTreeMap::new();
            pc.insert("turns".into(), Json::Num(turns as f64));
            pc.insert("hits".into(), Json::Num(hits as f64));
            pc.insert("hit_rows".into(), Json::Num(hit_rows as f64));
            pc.insert("prefill_chunks_off".into(),
                      Json::Num(pc_off as f64));
            pc.insert("prefill_chunks_on".into(),
                      Json::Num(pc_on as f64));
            root.insert("prefix_cache".into(), Json::Obj(pc));
            root.insert("chaos".into(), chaos.to_json());
        }
        let json = json.to_string();
        std::fs::write("BENCH_serving.json", format!("{json}\n"))
            .expect("write BENCH_serving.json");
        println!("recorded BENCH_serving.json");
    }
    println!("bench_serving OK");
}
