//! Microbenchmark of the paper's core trick: output-block rescaling by
//! integer addition (Lemma 3.1) vs floating-point multiplication.
//!
//! On Ascend the win is *architectural* (AtomicAdd in GM eliminates the
//! GM↔UB round trip); on a CPU the integer add is at best on par with
//! the FP multiply per element — what this bench pins is that the
//! MUL-by-ADD path costs no more than the multiply while enabling the
//! in-memory update, plus the cost of the guarded (zero-safe) variant
//! and the full AMLA-vs-Base recurrence at paper shape.

use amla::bench_util::{bb, Bench};
use amla::numerics::flash_base::{base_flash_attention, FlashConfig};
use amla::numerics::fp32::{mul_pow2_by_add, rescale_add, rescale_row, EXP_ONE};
use amla::numerics::amla::amla_attention;
use amla::numerics::Rng;

fn main() {
    let mut b = Bench::new("rescale");
    let mut rng = Rng::new(1);

    for size in [512usize, 128 * 512] {
        let base: Vec<f32> =
            (0..size).map(|_| rng.gaussian().abs() + 0.1).collect();

        // FP32 multiply (what [V2] does arithmetically)
        let mut buf = base.clone();
        b.bench_throughput(&format!("fp32_mul/{size}"), size as u64, || {
            let alpha = bb(0.4406868f32); // exp(m_prev - m_new) style
            for x in buf.iter_mut() {
                *x *= alpha;
            }
            buf[0]
        });

        // unguarded integer exponent add (pure Lemma 3.1)
        let mut buf = base.clone();
        b.bench_throughput(&format!("int_add_unguarded/{size}"),
                           size as u64, || {
            let add = bb(-1i32) * EXP_ONE;
            for x in buf.iter_mut() {
                *x = mul_pow2_by_add(*x, add / EXP_ONE);
            }
            buf[0]
        });

        // production guarded rescale (zero-safe, as in the kernel)
        let mut buf = base.clone();
        b.bench_throughput(&format!("rescale_row_guarded/{size}"),
                           size as u64, || {
            rescale_row(&mut buf, bb(-1) * EXP_ONE);
            buf[0]
        });
    }

    // compensation-add computation itself
    b.bench("rescale_add_compensated", || {
        rescale_add(bb(-2), bb(0.0031f32))
    });

    // full recurrences at one paper-shaped head group, 1K context
    let mut rng = Rng::new(2);
    let q = rng.gaussian_matrix(128, 576, 1.0);
    let k = rng.gaussian_matrix(1024, 576, 1.0);
    let v = rng.gaussian_matrix(1024, 512, 1.0);
    let cfg = FlashConfig { block_kv: 512, n1: 128, sq: 1, valid_len: 1024,
                            mixed_bf16: false };
    b.bench("amla_recurrence/g128_kv1024", || {
        amla_attention(bb(&q), bb(&k), bb(&v), &cfg)
    });
    b.bench("base_recurrence/g128_kv1024", || {
        base_flash_attention(bb(&q), bb(&k), bb(&v), &cfg)
    });

    b.finish();
}
