//! L3 coordinator benchmarks: serving throughput across batch/worker
//! configurations (host substrate — no PJRT variance), paged-cache
//! operations, and batcher overhead.
//!
//! This is the §Perf L3 target: the coordinator must not be the
//! bottleneck; the serving loop's non-kernel overhead per token is the
//! number to watch.

use amla::bench_util::{bb, Bench};
use amla::config::{Algo, ServeConfig};
use amla::coordinator::engine::SeqRuntime;
use amla::coordinator::{serve, Batcher, DecodeEngine, DecodeRequest,
                        HostLayerExecutor};
use amla::kvcache::{PagePool, SequenceCache};
use amla::numerics::mla::MlaDims;

fn dims() -> MlaDims {
    MlaDims { d_model: 64, n1: 2, d_head: 16, q_rank: 32, d_latent: 24,
              d_rope: 8, sq: 1 }
}

fn engine_fused(fuse: bool) -> DecodeEngine<HostLayerExecutor> {
    DecodeEngine::new(
        HostLayerExecutor::new(dims(), 2, Algo::Amla, 64, vec![64, 128], 3)
            .with_fuse(fuse),
        512, 16)
}

fn engine() -> DecodeEngine<HostLayerExecutor> {
    engine_fused(true)
}

/// Measure steady-state `step_batch` throughput (steps/s) for a batch
/// of `bsize` same-bucket sequences on `eng`.
fn step_batch_steps_per_sec(eng: &DecodeEngine<HostLayerExecutor>,
                            bsize: usize, workers: usize) -> f64 {
    let mut rts: Vec<SeqRuntime> =
        (0..bsize).map(|_| SeqRuntime::new(2)).collect();
    let mut toks = vec![0u32; bsize];
    // warm each sequence to a non-trivial context
    for step in 0..48u32 {
        let feeds: Vec<u32> =
            toks.iter().map(|&t| t.wrapping_add(step)).collect();
        let outs = eng.step_batch(&mut rts, &feeds, workers);
        for (t, o) in toks.iter_mut().zip(outs) {
            *t = o.unwrap();
        }
    }
    let t0 = std::time::Instant::now();
    let mut steps = 0u64;
    while t0.elapsed().as_secs_f64() < 0.5 {
        // keep context bounded: free + rebuild when near the bucket
        if rts[0].caches[0].len() > 100 {
            let mut pool = eng.pool.lock().unwrap();
            for rt in &mut rts {
                rt.free(&mut pool);
            }
            drop(pool);
            rts = (0..bsize).map(|_| SeqRuntime::new(2)).collect();
        }
        let feeds = toks.clone();
        let outs = eng.step_batch(&mut rts, &feeds, workers);
        for (t, o) in toks.iter_mut().zip(outs) {
            *t = o.unwrap();
        }
        steps += 1;
    }
    steps as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let mut b = Bench::new("coordinator");

    // serving throughput across (batch, batch_workers)
    println!("host-substrate serving throughput:");
    for (max_batch, workers) in [(1usize, 1usize), (4, 1), (4, 4), (8, 4)] {
        let eng = engine();
        let cfg = ServeConfig { max_batch, workers, batch_workers: workers,
                                pool_pages: 512, page_size: 16,
                                ..ServeConfig::default() };
        let reqs: Vec<_> = (0..8u64)
            .map(|i| DecodeRequest::new(i, vec![1, 2, 3], 6))
            .collect();
        let t0 = std::time::Instant::now();
        let report = serve(&eng, reqs, &cfg).unwrap();
        println!("  batch {max_batch} batch_workers {workers}: {:.0} tok/s \
                  ({} tokens in {:.2?}, occupancy {:.2})",
                 report.metrics.tokens_generated as f64
                     / t0.elapsed().as_secs_f64(),
                 report.metrics.tokens_generated, t0.elapsed(),
                 report.metrics.mean_batch_occupancy());
    }

    // batched decode steps/sec: the PR-1 number — the same 8-sequence
    // batch stepped by the (unfused) engine with 1 vs 4 workers.
    println!("\nbatched step_batch throughput (8 sequences, ctx ~48):");
    for workers in [1usize, 4] {
        let eng = engine_fused(false);
        let sps = step_batch_steps_per_sec(&eng, 8, workers);
        println!("  workers {workers}: {:.1} steps/s ({:.0} seq-tok/s)",
                 sps, sps * 8.0);
    }

    // fused vs threaded cross-sequence step_batch: the PR-2 tentpole —
    // a same-bucket batch of B sequences, one fused kernel call vs the
    // per-sequence worker pool (outputs are bit-identical; only the
    // call shape differs)
    println!("\nfused vs threaded step_batch (same-bucket batch):");
    let mut baseline: Vec<(String, f64)> = Vec::new();
    for bsize in [2usize, 8] {
        for fuse in [false, true] {
            let eng = engine_fused(fuse);
            let sps = step_batch_steps_per_sec(&eng, bsize, 4);
            let label = if fuse { "fused" } else { "threaded" };
            println!("  B {bsize} {label:<8}: {:.1} steps/s \
                      ({:.0} seq-tok/s)", sps, sps * bsize as f64);
            baseline.push((format!("step_batch/b{bsize}_{label}"), sps));
        }
    }
    // perf-trajectory baseline: BENCH_coordinator.json at the repo root
    // (opt-in so routine bench runs do not dirty the tree)
    if std::env::var("AMLA_BENCH_RECORD").is_ok() {
        let mut json = String::from(
            "{\n  \"bench\": \"coordinator\",\n  \
             \"metric\": \"steps_per_sec\",\n  \"configs\": {\n");
        for (i, (name, sps)) in baseline.iter().enumerate() {
            let sep = if i + 1 < baseline.len() { "," } else { "" };
            json.push_str(&format!("    \"{name}\": {sps:.2}{sep}\n"));
        }
        json.push_str("  }\n}\n");
        std::fs::write("BENCH_coordinator.json", &json)
            .expect("write BENCH_coordinator.json");
        println!("\nrecorded BENCH_coordinator.json");
    }

    // single decode step cost (host substrate)
    {
        let eng = engine();
        let mut rt = amla::coordinator::engine::SeqRuntime::new(2);
        let mut tok = 5u32;
        b.bench("decode_step_host", || {
            // reset when nearing the bucket limit
            if rt.caches[0].len() > 100 {
                let mut pool = eng.pool.lock().unwrap();
                rt.free(&mut pool);
                drop(pool);
                rt = amla::coordinator::engine::SeqRuntime::new(2);
            }
            tok = eng.step(&mut rt, bb(tok)).unwrap();
            tok
        });
    }

    // paged cache operations
    {
        let mut pool = PagePool::new(4096, 64, 512, 64);
        let mut seq = SequenceCache::new();
        let latent = vec![0.5f32; 512];
        let rope = vec![0.25f32; 64];
        b.bench("kvcache_append", || {
            if seq.len() >= 2048 {
                seq.free(&mut pool);
            }
            seq.append(&mut pool, bb(&latent), bb(&rope)).unwrap()
        });
        // ensure some content for materialize
        while seq.len() < 1500 {
            seq.append(&mut pool, &latent, &rope).unwrap();
        }
        let mut c = vec![0f32; 2048 * 512];
        let mut kr = vec![0f32; 2048 * 64];
        b.bench_throughput("kvcache_materialize/kv2048",
                           (2048 * 512) as u64, || {
            seq.materialize(&pool, 2048, &mut c, &mut kr);
            c[0]
        });
    }

    // batcher admission overhead
    {
        b.bench("batcher_admit_reap_cycle", || {
            let mut batcher = Batcher::new(8, 100_000);
            for i in 0..64u64 {
                batcher.enqueue(DecodeRequest::new(i, vec![1, 2], 1), 0.0);
            }
            let mut total = 0;
            while !batcher.idle() {
                total += batcher.admit(0.0);
                for st in batcher.active_mut() {
                    st.generated.push(1);
                }
                batcher.note_step();
                batcher.reap();
            }
            total
        });
    }

    b.finish();
}
