//! Offline stub of the `xla` crate (PJRT bindings).
//!
//! The container this repository builds in has no XLA/PJRT shared
//! library, so the real bindings cannot link.  This stub keeps every
//! call site type-checking while making the unavailability explicit at
//! runtime: [`PjRtClient::cpu`] — the entry point of every PJRT path —
//! returns an error, and the integration tests / examples that need
//! compiled artifacts already skip when `artifacts/manifest.json` is
//! missing.  The `HostLayerExecutor` substrate (bit-exact Rust
//! numerics) is the serving path actually exercised offline.
//!
//! Swap this path dependency for the real `xla` crate in Cargo.toml to
//! run against a PJRT runtime; the API surface mirrors xla_extension
//! 0.5.x as used by `rust/src/runtime/client.rs`.

use std::fmt;

const UNAVAILABLE: &str =
    "xla/PJRT runtime is not available in this offline build \
     (stub crate rust/vendor/xla)";

/// Error type of every stubbed operation.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Host-side literal (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

/// Device-resident buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Compiled executable handle (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T])
                      -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }

    pub fn execute_b<T>(&self, _args: &[T])
                        -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// PJRT client handle.  [`PjRtClient::cpu`] is the single runtime gate:
/// it errors, so no stubbed executable/buffer method is ever reached.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T>(&self, _data: &[T], _dims: &[usize],
                                      _device: Option<usize>)
                                      -> Result<PjRtBuffer, Error> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// Computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn literal_roundtrip_is_gated() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
