//! In-tree miniature of the `anyhow` crate (offline build).
//!
//! Implements the subset this repository uses: [`Error`] with a context
//! chain, [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros.  Like the real crate,
//! `{err}` displays the outermost message and `{err:#}` the full
//! `outer: ...: root` chain, and [`Error`] deliberately does **not**
//! implement `std::error::Error` so the blanket `From` conversion below
//! stays coherent.

use std::fmt;

/// A context-chained error value.
pub struct Error {
    /// Outermost context first; the last entry is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The `source()`-style chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        for cause in &self.chain[1..] {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — plain `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an error built as by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let r: Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        r?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e = io_fail().context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn with_context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("root {}", 7));
        let e = r.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "outer 1: root 7");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
    }
}
