//! End-to-end: the full serving stack over real PJRT layer artifacts.
//!
//! Coordinator -> batcher -> engine -> PJRT decode-layer executable ->
//! paged latent cache, with the HostLayerExecutor (bit-exact Rust
//! numerics) as the cross-check substrate.

use amla::config::{Algo, ServeConfig};
use amla::coordinator::{serve, DecodeEngine, DecodeRequest,
                        HostLayerExecutor, PjrtLayerExecutor};
use amla::numerics::mla::MlaDims;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        artifact_dir: "artifacts".into(),
        algo: Algo::Amla,
        n1: 16,
        sq: 1,
        max_batch: 2,
        page_size: 64,
        pool_pages: 64,
        workers: 2,
        max_new_tokens: 3,
    }
}

#[test]
fn pjrt_serving_completes_requests() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = serve_cfg();
    let dims = MlaDims { n1: cfg.n1, sq: cfg.sq, ..MlaDims::default() };
    let exec = PjrtLayerExecutor::new(&cfg, dims, 2, 42).expect("executor");
    let engine = DecodeEngine::new(exec, cfg.pool_pages, cfg.page_size);

    let requests: Vec<_> = (0..3)
        .map(|i| DecodeRequest::new(i, vec![10 + i as u32, 20, 30], 3))
        .collect();
    let report = serve(&engine, requests, &cfg).expect("serve");
    assert_eq!(report.results.len(), 3);
    for r in &report.results {
        assert_eq!(r.tokens.len(), 3, "request {} incomplete", r.id);
    }
    assert!(report.metrics.tokens_per_sec() > 0.0);
    // pool fully reclaimed
    assert_eq!(engine.pool.lock().unwrap().stats().allocated_pages, 0);
}

#[test]
fn pjrt_and_host_layer_steps_agree() {
    // The PJRT layer executable (JAX lowering, BF16 kernel) and the Rust
    // host path implement the same layer; one decode step must agree to
    // mixed-precision tolerance.  (Token-stream equality is NOT required
    // — the hashed readout amplifies bf16-vs-f32 noise by design.)
    use amla::coordinator::engine::LayerExecutor;
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = serve_cfg();
    let dims = MlaDims { n1: cfg.n1, sq: cfg.sq, ..MlaDims::default() };
    let host = HostLayerExecutor::new(dims, 2, Algo::Amla, 256,
                                      vec![256, 512, 1024, 2048], 42);
    let pjrt = PjrtLayerExecutor::new(&cfg, dims, 2, 42).expect("exec");

    let mut rng = amla::numerics::Rng::new(77);
    let bucket = 256;
    let valid = 40;
    let x: Vec<f32> = (0..dims.d_model).map(|_| rng.gaussian()).collect();
    let c0: Vec<f32> = (0..bucket * dims.d_latent)
        .map(|i| if i < valid * dims.d_latent { rng.gaussian() * 0.1 } else { 0.0 })
        .collect();
    let kr0: Vec<f32> = (0..bucket * dims.d_rope)
        .map(|i| if i < valid * dims.d_rope { rng.gaussian() * 0.1 } else { 0.0 })
        .collect();

    let (mut c_h, mut kr_h) = (c0.clone(), kr0.clone());
    let y_host = host.step(0, &x, &mut c_h, &mut kr_h, bucket, valid + 1)
        .expect("host step");
    let (mut c_p, mut kr_p) = (c0, kr0);
    let y_pjrt = pjrt.step(0, &x, &mut c_p, &mut kr_p, bucket, valid + 1)
        .expect("pjrt step");

    let err = amla::numerics::rel_frobenius_error(&y_pjrt, &y_host);
    assert!(err < 2e-2, "PJRT vs host layer output: rel err {err}");
    // both wrote the same new latent row (projections are f32 both sides)
    let row = valid * dims.d_latent;
    let err_c = amla::numerics::rel_frobenius_error(
        &c_p[row..row + dims.d_latent], &c_h[row..row + dims.d_latent]);
    assert!(err_c < 1e-3, "new latent row diverged: {err_c}");
}

#[test]
fn continuous_batching_on_pjrt() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut cfg = serve_cfg();
    cfg.max_batch = 2;
    let dims = MlaDims { n1: cfg.n1, sq: cfg.sq, ..MlaDims::default() };
    let exec = PjrtLayerExecutor::new(&cfg, dims, 1, 7).expect("executor");
    let engine = DecodeEngine::new(exec, cfg.pool_pages, cfg.page_size);
    let requests: Vec<_> = (0..5)
        .map(|i| DecodeRequest::new(i, vec![1, 2], 2))
        .collect();
    let report = serve(&engine, requests, &cfg).expect("serve");
    assert_eq!(report.metrics.requests_completed, 5);
    assert!(report.batcher.mean_occupancy() > 1.0,
            "occupancy {}", report.batcher.mean_occupancy());
}
