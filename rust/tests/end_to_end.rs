//! End-to-end: the full serving stack over real PJRT layer artifacts,
//! plus the batched-vs-serial exactness contract on the host substrate.
//!
//! Coordinator -> batcher -> engine -> PJRT decode-layer executable ->
//! paged latent cache, with the HostLayerExecutor (bit-exact Rust
//! numerics) as the cross-check substrate.  The batched tests need no
//! artifacts and always run.

use amla::config::{Algo, ServeConfig};
use amla::coordinator::{serve, DecodeEngine, DecodeRequest,
                        HostLayerExecutor, PjrtLayerExecutor};
use amla::numerics::mla::MlaDims;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        artifact_dir: "artifacts".into(),
        algo: Algo::Amla,
        n1: 16,
        sq: 1,
        max_batch: 2,
        page_size: 64,
        pool_pages: 64,
        workers: 2,
        max_new_tokens: 3,
        ..ServeConfig::default()
    }
}

#[test]
fn pjrt_serving_completes_requests() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = serve_cfg();
    let dims = MlaDims { n1: cfg.n1, sq: cfg.sq, ..MlaDims::default() };
    let exec = PjrtLayerExecutor::new(&cfg, dims, 2, 42).expect("executor");
    let engine = DecodeEngine::new(exec, cfg.pool_pages, cfg.page_size);

    let requests: Vec<_> = (0..3)
        .map(|i| DecodeRequest::new(i, vec![10 + i as u32, 20, 30], 3))
        .collect();
    let report = serve(&engine, requests, &cfg).expect("serve");
    assert_eq!(report.results.len(), 3);
    for r in &report.results {
        assert_eq!(r.tokens.len(), 3, "request {} incomplete", r.id);
    }
    assert!(report.metrics.tokens_per_sec() > 0.0);
    // pool fully reclaimed
    assert_eq!(engine.pool.lock().unwrap().stats().allocated_pages, 0);
}

#[test]
fn pjrt_and_host_layer_steps_agree() {
    // The PJRT layer executable (JAX lowering, BF16 kernel) and the Rust
    // host path implement the same layer; one decode step must agree to
    // mixed-precision tolerance.  (Token-stream equality is NOT required
    // — the hashed readout amplifies bf16-vs-f32 noise by design.)
    use amla::coordinator::engine::LayerExecutor;
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = serve_cfg();
    let dims = MlaDims { n1: cfg.n1, sq: cfg.sq, ..MlaDims::default() };
    let host = HostLayerExecutor::new(dims, 2, Algo::Amla, 256,
                                      vec![256, 512, 1024, 2048], 42);
    let pjrt = PjrtLayerExecutor::new(&cfg, dims, 2, 42).expect("exec");

    let mut rng = amla::numerics::Rng::new(77);
    let bucket = 256;
    let valid = 40;
    let x: Vec<f32> = (0..dims.d_model).map(|_| rng.gaussian()).collect();
    let c0: Vec<f32> = (0..bucket * dims.d_latent)
        .map(|i| if i < valid * dims.d_latent { rng.gaussian() * 0.1 } else { 0.0 })
        .collect();
    let kr0: Vec<f32> = (0..bucket * dims.d_rope)
        .map(|i| if i < valid * dims.d_rope { rng.gaussian() * 0.1 } else { 0.0 })
        .collect();

    let (mut c_h, mut kr_h) = (c0.clone(), kr0.clone());
    let y_host = host.step(0, &x, &mut c_h, &mut kr_h, bucket, valid + 1)
        .expect("host step");
    let (mut c_p, mut kr_p) = (c0, kr0);
    let y_pjrt = pjrt.step(0, &x, &mut c_p, &mut kr_p, bucket, valid + 1)
        .expect("pjrt step");

    let err = amla::numerics::rel_frobenius_error(&y_pjrt, &y_host);
    assert!(err < 2e-2, "PJRT vs host layer output: rel err {err}");
    // both wrote the same new latent row (projections are f32 both sides)
    let row = valid * dims.d_latent;
    let err_c = amla::numerics::rel_frobenius_error(
        &c_p[row..row + dims.d_latent], &c_h[row..row + dims.d_latent]);
    assert!(err_c < 1e-3, "new latent row diverged: {err_c}");
}

// ---- batched-parallel exactness (host substrate; always runs) --------

/// Mixed-bucket workload: prompt/generation lengths chosen so the batch
/// spans both the 64 and 128 KV buckets at the same time.
fn mixed_bucket_requests() -> Vec<DecodeRequest> {
    vec![
        DecodeRequest::new(0, vec![1, 2, 3], 6),
        DecodeRequest::new(1, vec![9; 60], 12),      // crosses into 128
        DecodeRequest::new(2, vec![4, 5], 4),
        DecodeRequest::new(3, vec![7; 30], 8),
        DecodeRequest::new(4, vec![11, 12, 13, 14], 10),
        DecodeRequest::new(5, vec![2; 50], 20),      // crosses into 128
        DecodeRequest::new(6, vec![3], 5),
        DecodeRequest::new(7, vec![8; 10], 7),
    ]
}

fn host_engine_fused(algo: Algo, fuse: bool)
                     -> DecodeEngine<HostLayerExecutor> {
    let dims = MlaDims { d_model: 64, n1: 2, d_head: 16, q_rank: 32,
                         d_latent: 24, d_rope: 8, sq: 1 };
    let exec = HostLayerExecutor::new(dims, 2, algo, 32, vec![64, 128], 7)
        .with_fuse(fuse);
    DecodeEngine::new(exec, 1024, 16)
}

fn host_engine(algo: Algo) -> DecodeEngine<HostLayerExecutor> {
    host_engine_fused(algo, true)
}

fn serve_tokens(algo: Algo, max_batch: usize, batch_workers: usize,
                fuse: bool) -> Vec<(u64, Vec<u32>)> {
    let engine = host_engine_fused(algo, fuse);
    let cfg = ServeConfig { max_batch, batch_workers, workers: batch_workers,
                            pool_pages: 1024, page_size: 16,
                            fuse_buckets: fuse,
                            ..ServeConfig::default() };
    let report = serve(&engine, mixed_bucket_requests(), &cfg)
        .expect("serve");
    assert_eq!(report.metrics.requests_completed, 8);
    assert_eq!(engine.pool.lock().unwrap().stats().allocated_pages, 0,
               "pages leaked");
    if fuse && max_batch >= 4 {
        assert!(report.metrics.fused_groups > 0,
                "fused route never taken at max_batch {max_batch}");
    }
    if !fuse {
        assert_eq!(report.metrics.fused_groups, 0);
    }
    let mut toks: Vec<(u64, Vec<u32>)> = report.results.into_iter()
        .map(|r| (r.id, r.tokens))
        .collect();
    toks.sort_by_key(|(id, _)| *id);
    toks
}

// contract:5 batched-parallelism exactness (workers 1..N bit-identical)
#[test]
fn batched_parallel_bit_identical_to_serial() {
    // The tentpole contract: a mixed-bucket batch served with the
    // parallel worker pool and/or the fused cross-sequence kernel must
    // emit exactly the serial path's tokens, for both algorithms and
    // across batch sizes — every (fuse, workers, max_batch) cell of the
    // matrix is bit-identical.
    for algo in [Algo::Amla, Algo::Base] {
        let serial = serve_tokens(algo, 4, 1, false);
        for fuse in [false, true] {
            for workers in [1usize, 4] {
                for max_batch in [4usize, 8] {
                    let got = serve_tokens(algo, max_batch, workers, fuse);
                    assert_eq!(got, serial,
                               "algo {:?} max_batch {max_batch} \
                                workers {workers} fuse {fuse} \
                                diverged from serial",
                               algo);
                }
            }
        }
    }
}

#[test]
fn engine_step_batch_matches_sequential_engine_steps() {
    use amla::coordinator::engine::SeqRuntime;
    // drive the same prompts through engine.step (one sequence at a
    // time) and engine.step_batch (whole batch, 4 workers); every fed
    // token's output must match bit-for-bit.
    let prompts: Vec<Vec<u32>> = vec![
        vec![5, 6, 7],
        vec![1; 40],
        vec![2, 3],
        vec![9; 70], // 128 bucket
    ];
    let serial: Vec<Vec<u32>> = {
        let eng = host_engine(Algo::Amla);
        prompts.iter().map(|p| {
            let mut rt = SeqRuntime::new(2);
            let mut outs = Vec::new();
            for &t in p {
                outs.push(eng.step(&mut rt, t).unwrap());
            }
            outs
        }).collect()
    };
    let eng = host_engine(Algo::Amla);
    let mut rts: Vec<SeqRuntime> =
        (0..prompts.len()).map(|_| SeqRuntime::new(2)).collect();
    let batched = amla::testing::drive_prompts(&eng, &mut rts, &prompts, 4);
    assert_eq!(batched, serial);
}

#[test]
fn continuous_batching_on_pjrt() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut cfg = serve_cfg();
    cfg.max_batch = 2;
    let dims = MlaDims { n1: cfg.n1, sq: cfg.sq, ..MlaDims::default() };
    let exec = PjrtLayerExecutor::new(&cfg, dims, 1, 7).expect("executor");
    let engine = DecodeEngine::new(exec, cfg.pool_pages, cfg.page_size);
    let requests: Vec<_> = (0..5)
        .map(|i| DecodeRequest::new(i, vec![1, 2], 2))
        .collect();
    let report = serve(&engine, requests, &cfg).expect("serve");
    assert_eq!(report.metrics.requests_completed, 5);
    assert!(report.batcher.mean_occupancy() > 1.0,
            "occupancy {}", report.batcher.mean_occupancy());
}
