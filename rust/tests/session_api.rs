//! Session-API regression tier: mid-flight cancellation accounting,
//! priority-class scheduling properties, live streaming, and the
//! wrapper bit-identity contract.
//!
//! The scripted tests drive the one session loop
//! (`amla::serving::run_scripted`) deterministically under the virtual
//! clock — a `SessionCue` fires a cancel at an exact step / token
//! count, so "cancel mid-prefill-chunk" and "cancel mid-decode" are
//! reproducible instants, not races.  The live tests exercise the
//! threaded `AmlaEngine` frontend with bounded-channel backpressure so
//! incremental observation and mid-flight cancellation are guaranteed
//! by construction (the engine cannot run ahead of the client).

use amla::config::{Algo, EngineConfig, ServeConfig};
use amla::coordinator::{DecodeEngine, DecodeRequest, HostLayerExecutor,
                        Outcome, Priority, RequestId, TracedRequest};
use amla::numerics::mla::MlaDims;
use amla::serving::clock::SimClock;
use amla::serving::{run_scripted, serve_open_loop, AmlaEngine,
                    ScriptedCommand, SessionAction, SessionSubmit,
                    StepCostModel, SubmitOptions};
use amla::util::prop::{gen_usize, run_prop};

fn host_executor() -> HostLayerExecutor {
    let dims = MlaDims { d_model: 48, n1: 2, d_head: 12, q_rank: 24,
                         d_latent: 16, d_rope: 8, sq: 1 };
    HostLayerExecutor::new(dims, 2, Algo::Amla, 32, vec![32, 64], 11)
}

/// Real pool is generous (512 pages); admission pressure comes from
/// the cfg's `pool_pages` *budget*, like the serving test tier.
fn engine() -> DecodeEngine<HostLayerExecutor> {
    DecodeEngine::new(host_executor(), 512, 8)
}

fn vclock() -> SimClock {
    SimClock::simulated(StepCostModel::new(0.01, 0.0))
}

/// pool budget rows/layer = pool_pages * page_size(8) / n_layers(2)
fn cfg(preempt: bool) -> ServeConfig {
    ServeConfig { max_batch: 4, workers: 2, batch_workers: 2,
                  page_size: 8, preempt, starvation_steps: 2,
                  ..ServeConfig::default() }
}

fn submit_all(subs: Vec<SessionSubmit>) -> Vec<ScriptedCommand> {
    vec![
        ScriptedCommand::immediately(SessionAction::Submit(subs)),
        ScriptedCommand::immediately(SessionAction::Drain),
    ]
}

fn tokens_by_id(results: &[amla::coordinator::DecodeResult])
                -> Vec<(RequestId, Vec<u32>)> {
    let mut t: Vec<_> = results.iter()
        .map(|r| (r.id, r.tokens.clone()))
        .collect();
    t.sort_by_key(|(id, _)| *id);
    t
}

// ---------------------------------------------------------------------
// Cancellation accounting (the PR-1 abort-contract audit)
// ---------------------------------------------------------------------

// contract:7 cancellation accounting — exact credit, pool back to zero
#[test]
fn cancel_mid_decode_credits_exact_budget_and_frees_pool() {
    // 48-row/layer budget.  r0 (3 + 40 = 43 rows) decodes; r1 needs
    // the ENTIRE budget (8 + 40 = 48 rows), so it can only ever admit
    // if cancellation credits r0's admitted_rows verbatim.  The cancel
    // fires deterministically after r0's 5th token (mid-decode).
    let eng = engine();
    let mut clock = vclock();
    let mut c = cfg(false);
    c.pool_pages = 12;
    let subs = vec![
        SessionSubmit::new(DecodeRequest::new(0, vec![1, 2, 3], 40))
            .at(0.0),
        SessionSubmit::new(DecodeRequest::new(1, vec![4; 8], 40)).at(0.0),
    ];
    let script = vec![
        ScriptedCommand::immediately(SessionAction::Submit(subs)),
        ScriptedCommand::after_tokens(0, 5, SessionAction::Cancel(0)),
        ScriptedCommand::immediately(SessionAction::Drain),
    ];
    let report = run_scripted(&eng, &c, &mut clock, script).unwrap();

    let toks = tokens_by_id(&report.results);
    assert_eq!(toks.len(), 2);
    let r0 = report.results.iter().find(|r| r.id == 0).unwrap();
    assert_eq!(r0.status, Outcome::Cancelled);
    assert_eq!(r0.tokens.len(), 5,
               "cancel must land exactly after the 5th token");
    let r1 = report.results.iter().find(|r| r.id == 1).unwrap();
    assert_eq!(r1.status, Outcome::Completed);
    assert_eq!(r1.tokens.len(), 40,
               "full-budget request must admit after the credit");
    assert_eq!(report.completion_order, vec![0, 1]);
    assert_eq!(report.metrics.requests_cancelled, 1);
    assert_eq!(report.metrics.requests_completed, 1);
    assert_eq!(report.batcher.cancelled, 1);
    assert_eq!(eng.pool.lock().unwrap().stats().allocated_pages, 0,
               "cancelled sequence leaked pool pages");
}

#[test]
fn cancel_mid_prefill_chunk_frees_everything() {
    // 28-row/layer budget, prefill chunk 4.  r0 (20 + 8 = 28 rows)
    // is cancelled after exactly 2 chunk steps — 8 of 20 prompt tokens
    // consumed, zero tokens generated, squarely mid-prefill.  r1 then
    // needs the whole budget (4 + 24 = 28 rows).
    let eng = engine();
    let mut clock = vclock();
    let mut c = cfg(false);
    c.pool_pages = 7;
    c.prefill_chunk = 4;
    let subs = vec![
        SessionSubmit::new(DecodeRequest::new(0, vec![9; 20], 8)).at(0.0),
        SessionSubmit::new(DecodeRequest::new(1, vec![5; 4], 24)).at(0.0),
    ];
    let script = vec![
        ScriptedCommand::immediately(SessionAction::Submit(subs)),
        ScriptedCommand::after_steps(2, SessionAction::Cancel(0)),
        ScriptedCommand::immediately(SessionAction::Drain),
    ];
    let report = run_scripted(&eng, &c, &mut clock, script).unwrap();

    let r0 = report.results.iter().find(|r| r.id == 0).unwrap();
    assert_eq!(r0.status, Outcome::Cancelled);
    assert!(r0.tokens.is_empty(),
            "cancelled mid-prefill: no tokens were generated");
    let r1 = report.results.iter().find(|r| r.id == 1).unwrap();
    assert_eq!(r1.status, Outcome::Completed);
    assert_eq!(r1.tokens.len(), 24);
    // exactly 2 chunks of r0's prompt were consumed before the cancel
    assert_eq!(report.metrics.prompt_tokens, 8 + 4);
    assert_eq!(report.metrics.prefill_chunks, 2 + 1);
    assert_eq!(report.metrics.requests_cancelled, 1);
    assert_eq!(eng.pool.lock().unwrap().stats().allocated_pages, 0,
               "mid-prefill cancel leaked pool pages");
}

#[test]
fn cancel_of_unknown_or_finished_request_is_noop() {
    let eng = engine();
    let mut clock = vclock();
    let mut c = cfg(false);
    c.pool_pages = 128;
    let subs = vec![
        SessionSubmit::new(DecodeRequest::new(0, vec![1, 2, 3], 3)).at(0.0),
    ];
    let script = vec![
        ScriptedCommand::immediately(SessionAction::Submit(subs)),
        ScriptedCommand::immediately(SessionAction::Cancel(99)),
        // r0 finishes at step 3; this cue can then never fire and is
        // forced once the engine idles — by which point r0 is gone
        ScriptedCommand::after_steps(1000, SessionAction::Cancel(0)),
        ScriptedCommand::immediately(SessionAction::Drain),
    ];
    let report = run_scripted(&eng, &c, &mut clock, script).unwrap();
    assert_eq!(report.results.len(), 1);
    assert_eq!(report.results[0].status, Outcome::Completed);
    assert_eq!(report.results[0].tokens.len(), 3);
    assert_eq!(report.metrics.requests_cancelled, 0);
    assert_eq!(eng.pool.lock().unwrap().stats().allocated_pages, 0);
}

#[test]
fn cancel_of_queued_request_returns_no_tokens_and_no_credit_damage() {
    // r1 is cancelled while still QUEUED (pool-blocked behind r0):
    // nothing was deducted, so nothing may be credited — afterwards the
    // budget still fits exactly r2.
    let eng = engine(); // 48 rows/layer
    let mut clock = vclock();
    let mut c = cfg(false);
    c.pool_pages = 12;
    let subs = vec![
        SessionSubmit::new(DecodeRequest::new(0, vec![1, 2], 38)).at(0.0),
        SessionSubmit::new(DecodeRequest::new(1, vec![2; 4], 20)).at(0.0),
        SessionSubmit::new(DecodeRequest::new(2, vec![3; 8], 40)).at(0.0),
    ];
    let script = vec![
        ScriptedCommand::immediately(SessionAction::Submit(subs)),
        ScriptedCommand::after_steps(1, SessionAction::Cancel(1)),
        ScriptedCommand::immediately(SessionAction::Drain),
    ];
    let report = run_scripted(&eng, &c, &mut clock, script).unwrap();
    let r1 = report.results.iter().find(|r| r.id == 1).unwrap();
    assert_eq!(r1.status, Outcome::Cancelled);
    assert!(r1.tokens.is_empty());
    let r2 = report.results.iter().find(|r| r.id == 2).unwrap();
    assert_eq!(r2.status, Outcome::Completed);
    assert_eq!(r2.tokens.len(), 40, "full-budget r2 must still admit");
    assert_eq!(report.metrics.requests_cancelled, 1);
    assert_eq!(eng.pool.lock().unwrap().stats().allocated_pages, 0);
}

// ---------------------------------------------------------------------
// Priority-class scheduling
// ---------------------------------------------------------------------

#[test]
fn interactive_admits_before_batch_under_saturated_pool() {
    // 12-row/layer budget.  An Interactive filler (submitted first,
    // FIFO within its class) fills the pool; four same-shape requests
    // (2 batch, 2 interactive) queue behind it with identical arrival
    // stamps.  As budget frees, the Interactive class must drain
    // first, one at a time (each needs 8 of the 12 rows).
    let eng = engine();
    let mut clock = vclock();
    let mut c = cfg(false);
    c.pool_pages = 3;
    let mk = |id| DecodeRequest::new(id, vec![10 + id as u32, 2], 6);
    let subs = vec![
        SessionSubmit::new(DecodeRequest::new(0, vec![1, 2], 10))
            .at(0.0)
            .priority(Priority::Interactive),
        SessionSubmit::new(mk(1)).at(0.0).priority(Priority::Batch),
        SessionSubmit::new(mk(2)).at(0.0).priority(Priority::Batch),
        SessionSubmit::new(mk(3)).at(0.0).priority(Priority::Interactive),
        SessionSubmit::new(mk(4)).at(0.0).priority(Priority::Interactive),
    ];
    let report = run_scripted(&eng, &c, &mut clock, submit_all(subs))
        .unwrap();
    assert_eq!(report.completion_order, vec![0, 3, 4, 1, 2],
               "interactive class must drain before batch");
    let delay = |id: RequestId| report.results.iter()
        .find(|r| r.id == id).unwrap().queue_delay;
    assert!(delay(3) < delay(1) && delay(3) < delay(2));
    assert!(delay(4) < delay(1) && delay(4) < delay(2));
    assert_eq!(report.metrics.queue_depth_peak[Priority::Batch.rank()], 2);
    assert_eq!(
        report.metrics.queue_depth_peak[Priority::Interactive.rank()], 3);
    assert_eq!(eng.pool.lock().unwrap().stats().allocated_pages, 0);
}

#[test]
fn prop_interactive_queue_delay_never_worse_than_batch() {
    // Property: with every request arriving at t=0 behind a
    // pool-filling resident, every Interactive queue delay <= every
    // Batch queue delay <= every Background queue delay, for random
    // shapes and class assignments.
    run_prop("priority_queue_delay", 12, |rng| {
        let n = gen_usize(rng, 3, 8);
        let classes = [Priority::Interactive, Priority::Batch,
                       Priority::Background];
        let mut subs = vec![
            SessionSubmit::new(DecodeRequest::new(0, vec![1, 2], 10))
                .at(0.0),
        ];
        let mut assigned: Vec<(RequestId, Priority)> = Vec::new();
        for i in 0..n {
            let id = i as RequestId + 1;
            let prompt = gen_usize(rng, 1, 4);
            let gen = gen_usize(rng, 2, 8);
            let class = classes[gen_usize(rng, 0, 3)];
            assigned.push((id, class));
            subs.push(
                SessionSubmit::new(
                    DecodeRequest::new(id, vec![7 + id as u32; prompt],
                                       gen))
                    .at(0.0)
                    .priority(class));
        }
        let eng = engine(); // 12-row/layer budget: saturated
        let mut clock = vclock();
        let mut c = cfg(false);
        c.pool_pages = 3;
        let report = run_scripted(&eng, &c, &mut clock,
                                  submit_all(subs)).unwrap();
        let delay = |id: RequestId| report.results.iter()
            .find(|r| r.id == id).unwrap().queue_delay;
        for &(a, ca) in &assigned {
            for &(b, cb) in &assigned {
                if ca < cb {
                    assert!(delay(a) <= delay(b),
                            "{ca:?} req {a} delayed {} vs {cb:?} req {b} \
                             {}", delay(a), delay(b));
                }
            }
        }
        assert_eq!(eng.pool.lock().unwrap().stats().allocated_pages, 0);
    });
}

#[test]
fn priority_preemption_evicts_background_before_batch() {
    // Two long residents (one Background, one Batch) fill the pool; a
    // small Interactive request starves behind them.  The preemptor
    // must evict the BACKGROUND resident even though both are
    // eligible, and recompute-resume must keep tokens bit-identical to
    // an unconstrained run.
    let run = |pool_pages: usize| {
        let eng = engine();
        let mut clock = vclock();
        let mut c = cfg(true);
        c.pool_pages = pool_pages;
        let subs = vec![
            SessionSubmit::new(DecodeRequest::new(0, vec![1, 2], 20))
                .at(0.0)
                .priority(Priority::Background),
            SessionSubmit::new(DecodeRequest::new(1, vec![3, 4], 20))
                .at(0.0)
                .priority(Priority::Batch),
            SessionSubmit::new(DecodeRequest::new(2, vec![5, 6], 4))
                .at(0.05)
                .priority(Priority::Interactive),
        ];
        let report = run_scripted(&eng, &c, &mut clock,
                                  submit_all(subs)).unwrap();
        assert_eq!(eng.pool.lock().unwrap().stats().allocated_pages, 0);
        report
    };
    // 44-row/layer budget: residents take 22 + 22, r2 (6 rows) starves
    let constrained = run(11);
    assert!(constrained.metrics.preemptions > 0,
            "pool pressure must trigger eviction");
    assert_eq!(constrained.batcher.preempted,
               constrained.metrics.preemptions);
    // the evicted (recompute-resumed) resident finishes last — and it
    // must be the Background one
    assert_eq!(*constrained.completion_order.last().unwrap(), 0,
               "preemption must pick the Background resident");
    let unconstrained = run(128);
    assert_eq!(unconstrained.metrics.preemptions, 0);
    assert_eq!(tokens_by_id(&constrained.results),
               tokens_by_id(&unconstrained.results),
               "priority preemption broke recompute bit-identity");
}

#[test]
fn priority_preemption_respects_anti_livelock_guard() {
    // The starved Interactive head needs MORE work than any resident
    // has remaining: the progress guard must win over priority — no
    // eviction, FIFO wait, everything completes.
    let eng = engine(); // 20-row/layer budget
    let mut clock = vclock();
    let mut c = cfg(true);
    c.pool_pages = 5;
    let subs = vec![
        SessionSubmit::new(DecodeRequest::new(0, vec![1, 2], 8))
            .at(0.0)
            .priority(Priority::Background), // 10 rows, 10 steps total
        SessionSubmit::new(DecodeRequest::new(1, vec![3, 4], 18))
            .at(0.05)
            .priority(Priority::Interactive), // needs all 20 rows
    ];
    let report = run_scripted(&eng, &c, &mut clock, submit_all(subs))
        .unwrap();
    assert_eq!(report.metrics.preemptions, 0,
               "priority must never override the progress guard");
    assert_eq!(report.completion_order, vec![0, 1]);
    for r in &report.results {
        assert_eq!(r.status, Outcome::Completed);
    }
    assert_eq!(eng.pool.lock().unwrap().stats().allocated_pages, 0);
}

// contract:6 wrapper bit-identity — one session loop under the hood
#[test]
fn uniform_priority_is_bit_identical_to_fifo_wrapper() {
    // A session whose requests all carry one class — any class — must
    // reproduce the pre-redesign FIFO schedule exactly (tokens,
    // completion order, makespan bits).  The wrapper run is itself the
    // FIFO reference (pinned against the committed golden trace by
    // rust/tests/open_loop_golden.rs).
    let trace = || {
        vec![
            TracedRequest { request: DecodeRequest::new(0, vec![1, 2, 3], 24),
                            arrival: 0.0 },
            TracedRequest { request: DecodeRequest::new(1, vec![4; 4], 24),
                            arrival: 0.0 },
            TracedRequest { request: DecodeRequest::new(2, vec![8, 9], 4),
                            arrival: 0.05 },
        ]
    };
    let mut c = cfg(true);
    c.pool_pages = 14; // 56-row budget: preemption fires
    c.starvation_steps = 4;
    let fifo = {
        let eng = engine();
        let mut clock = vclock();
        let r = serve_open_loop(&eng, trace(), &c, &mut clock).unwrap();
        (tokens_by_id(&r.results), r.completion_order.clone(),
         r.makespan.to_bits(), r.metrics.preemptions)
    };
    assert!(fifo.3 > 0, "reference run must actually preempt");
    for class in [Priority::Interactive, Priority::Batch,
                  Priority::Background] {
        let eng = engine();
        let mut clock = vclock();
        let subs = trace().into_iter()
            .map(|t| SessionSubmit::new(t.request)
                .at(t.arrival)
                .priority(class))
            .collect();
        let r = run_scripted(&eng, &c, &mut clock, submit_all(subs))
            .unwrap();
        let got = (tokens_by_id(&r.results), r.completion_order.clone(),
                   r.makespan.to_bits(), r.metrics.preemptions);
        assert_eq!(got, fifo,
                   "uniform {class:?} session diverged from FIFO");
    }
}

// ---------------------------------------------------------------------
// Live streaming sessions (threaded AmlaEngine)
// ---------------------------------------------------------------------

fn live_config(pool_pages: usize) -> EngineConfig {
    EngineConfig::builder()
        .pool_pages(pool_pages)
        .page_size(8)
        .max_batch(4)
        .batch_workers(2)
        .preempt(false)
        .build()
        .unwrap()
}

#[test]
fn live_session_streams_incrementally_with_backpressure() {
    // stream_capacity 2 bounds how far the engine can run ahead of the
    // client, so observing tokens before completion is guaranteed by
    // construction, not by timing.
    let engine = AmlaEngine::start(live_config(16), host_executor())
        .unwrap();
    let mut h = engine
        .submit_with(DecodeRequest::new(0, vec![5, 6, 7], 30),
                     SubmitOptions::default().stream_capacity(2))
        .unwrap();
    let first = h.next_token().expect("first token streams");
    let mut streamed = vec![first];
    streamed.extend(h.tokens());
    assert_eq!(streamed.len(), 30);
    let res = h.wait().unwrap();
    assert_eq!(res.status, Outcome::Completed);
    assert_eq!(res.tokens, streamed,
               "streamed tokens must equal the terminal result's");
    // live snapshot between requests: the session is drained but alive
    let snapshot = engine.metrics().unwrap();
    assert_eq!(snapshot.requests_completed, 1);
    assert_eq!(snapshot.active_sessions, 0);
    assert_eq!(snapshot.streamed_tokens, 30);
    // submit AFTER the engine served a request: long-lived session
    let res2 = engine
        .submit(DecodeRequest::new(1, vec![9, 9], 5))
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(res2.tokens.len(), 5);
    let report = engine.shutdown().unwrap();
    assert_eq!(report.metrics.requests_completed, 2);
    assert_eq!(report.metrics.streamed_tokens, 35);
    assert_eq!(report.metrics.requests_cancelled, 0);
}

#[test]
fn live_snapshot_sees_in_flight_session() {
    // stream_capacity 1 with nothing drained: the engine stalls after
    // ~2 tokens of 60, so the request CANNOT have completed when the
    // snapshot is taken — and the stall must stay command-responsive
    // (the snapshot is answered mid-stall, the deadlock regression of
    // the backpressure design).
    let engine = AmlaEngine::start(live_config(16), host_executor())
        .unwrap();
    let h = engine
        .submit_with(DecodeRequest::new(0, vec![1, 2, 3, 4], 60),
                     SubmitOptions::default().stream_capacity(1))
        .unwrap();
    let snapshot = engine.metrics().unwrap();
    assert_eq!(snapshot.requests_completed, 0,
               "snapshot must precede completion");
    let in_system: u64 = snapshot.queue_depth.iter().sum::<u64>()
        + snapshot.active_sessions;
    assert_eq!(in_system, 1, "one session queued or active");
    let res = h.wait().unwrap();
    assert_eq!(res.tokens.len(), 60);
    let report = engine.shutdown().unwrap();
    assert_eq!(report.metrics.requests_completed, 1);
}

#[test]
fn live_cancel_mid_flight_credits_budget_and_keeps_serving() {
    // 64-row/layer pool.  r0 needs the whole budget (4 + 60); with
    // stream_capacity 1 the engine is throttled to the client, so
    // cancelling after the first token is guaranteed mid-flight.  r1
    // then needs the whole budget again — it only admits if the cancel
    // credited r0 exactly.
    let engine = AmlaEngine::start(live_config(16), host_executor())
        .unwrap();
    let mut h = engine
        .submit_with(DecodeRequest::new(0, vec![1, 2, 3, 4], 60),
                     SubmitOptions::default().stream_capacity(1))
        .unwrap();
    let _first = h.next_token().expect("first token streams");
    h.cancel();
    let res = h.wait().unwrap();
    assert_eq!(res.status, Outcome::Cancelled);
    assert!(!res.tokens.is_empty(), "cancel landed after a token");
    assert!(res.tokens.len() < 60,
            "cancel must land mid-flight, got a full generation");
    // the full budget is back: another whole-pool request completes
    let res2 = engine
        .submit(DecodeRequest::new(1, vec![5, 6, 7, 8], 60))
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(res2.status, Outcome::Completed);
    assert_eq!(res2.tokens.len(), 60,
               "cancelled request leaked admission budget");
    let report = engine.shutdown().unwrap();
    assert_eq!(report.metrics.requests_cancelled, 1);
    assert_eq!(report.metrics.requests_completed, 1);
    assert_eq!(report.batcher.cancelled, 1);
}

#[test]
fn five_hundred_stalled_streams_stay_command_responsive() {
    // The slow-consumer flood regression: 500 capacity-1 streams, ten
    // of them kept by adversarially slow consumers (zero drained up
    // front), the other 490 abandoned outright.  The engine stalls on
    // the first kept stream's second token — from inside that stall it
    // must still answer metrics, process a cancel, and honor shutdown;
    // abandoned handles must not leak result slots (every one of the
    // 500 requests reaches the final report exactly once).
    let engine = AmlaEngine::start(live_config(64), host_executor())
        .unwrap();
    let mut kept = Vec::new();
    for i in 0..500u64 {
        let h = engine
            .submit_with(DecodeRequest::new(i, vec![3 + (i % 13) as u32], 3),
                         SubmitOptions::default().stream_capacity(1))
            .unwrap();
        if i % 50 == 0 {
            kept.push(h);
        }
        // the other handles drop here: abandoned consumers
    }
    // metrics answered from inside the stalled flood
    let snap = engine.metrics().unwrap();
    assert!(snap.requests_completed < 500,
            "snapshot must land mid-flood");
    let in_system: u64 = snap.queue_depth.iter().sum::<u64>()
        + snap.active_sessions;
    assert!(in_system > 0, "the flood must still be in the system");
    // cancel a deep-queued request from inside the stall
    let mut doomed = kept.pop().unwrap();
    doomed.cancel();
    let res = doomed.wait().unwrap();
    assert_eq!(res.status, Outcome::Cancelled,
               "cancel must be processed while the engine is stalled");
    assert!(res.tokens.is_empty(), "request 450 was cancelled queued");
    // one adversarially slow sip from the stream holding the stall
    assert!(kept[0].next_token().is_some(),
            "stalled stream must still deliver on demand");
    // shutdown drains: stalled buffers disconnect instead of wedging
    let report = engine.shutdown().unwrap();
    assert_eq!(report.results.len(), 500,
               "every request must reach the final report");
    assert_eq!(report.completion_order.len(), 500);
    assert_eq!(report.metrics.requests_cancelled, 1);
    assert_eq!(report.metrics.requests_completed, 499);
    for r in &report.results {
        if r.id == 450 {
            continue;
        }
        assert_eq!(r.status, Outcome::Completed,
                   "request {} lost to the flood", r.id);
        assert_eq!(r.tokens.len(), 3,
                   "request {} lost tokens to a stalled stream", r.id);
    }
}

// ---------------------------------------------------------------------
// Prefix-cache pool accounting (the shared-page cancellation audit)
// ---------------------------------------------------------------------

/// The 9-token opening prompt and its deterministic 8-token generation
/// — the transcript every follow-up below extends.
fn opening_transcript() -> (Vec<u32>, Vec<u32>) {
    let prompt: Vec<u32> = (40..49).collect();
    let eng = engine();
    let mut c = cfg(false);
    c.pool_pages = 128;
    let r = amla::coordinator::serve(
        &eng, vec![DecodeRequest::new(0, prompt.clone(), 8)], &c).unwrap();
    (prompt, r.results[0].tokens.clone())
}

#[test]
fn prefix_hit_admits_on_unique_rows_and_cancel_credits_the_stamp() {
    // 20-row/layer budget.  r1's prompt extends r0's published
    // transcript: raw need 29 rows exceeds the WHOLE budget, so r1 can
    // only ever admit if admission charges just its unique rows
    // (29 - 16 shared = 13).  Cancelling r1 after its 3rd token must
    // credit exactly that discounted stamp: full-budget r2 (20 rows)
    // then admits and completes.
    let (prompt_a, gen_a) = opening_transcript();
    let mut prompt_b = prompt_a.clone();
    prompt_b.extend_from_slice(&gen_a);
    prompt_b.extend([900, 901, 902, 903]); // 21 tokens
    let eng = engine();
    let mut clock = vclock();
    let mut c = cfg(false);
    c.pool_pages = 5; // 20 rows/layer
    c.prefix_cache = true;
    let subs = vec![
        SessionSubmit::new(DecodeRequest::new(0, prompt_a.clone(), 8))
            .at(0.0),
        SessionSubmit::new(DecodeRequest::new(1, prompt_b, 8)).at(2.0),
        SessionSubmit::new(DecodeRequest::new(2, (700..708).collect(), 12))
            .at(4.0),
    ];
    let script = vec![
        ScriptedCommand::immediately(SessionAction::Submit(subs)),
        ScriptedCommand::after_tokens(1, 3, SessionAction::Cancel(1)),
        ScriptedCommand::immediately(SessionAction::Drain),
    ];
    let report = run_scripted(&eng, &c, &mut clock, script).unwrap();
    let r1 = report.results.iter().find(|r| r.id == 1).unwrap();
    assert_eq!(r1.status, Outcome::Cancelled);
    assert_eq!(r1.tokens.len(), 3,
               "cancel must land exactly after the 3rd token");
    let r2 = report.results.iter().find(|r| r.id == 2).unwrap();
    assert_eq!(r2.status, Outcome::Completed);
    assert_eq!(r2.tokens.len(), 12,
               "full-budget r2 must admit after the exact credit");
    assert_eq!(report.metrics.prefix_hits, 1);
    assert_eq!(report.metrics.prefix_hit_rows, 16,
               "two whole 8-row pages attach");
    assert_eq!(report.metrics.requests_cancelled, 1);
    assert_eq!(eng.pool.lock().unwrap().stats().allocated_pages, 0,
               "shared-page cancel leaked pool pages");
}

#[test]
fn cancel_of_queued_follow_up_releases_its_reservation() {
    // r2's admission probe pins the matched pages into a reservation
    // while it is pool-blocked behind the full-budget filler r1;
    // cancelling it while QUEUED must release those pinned references
    // (the pool must fully drain) and credit nothing — nothing was
    // admitted.
    let (prompt_a, gen_a) = opening_transcript();
    let mut prompt_b = prompt_a.clone();
    prompt_b.extend_from_slice(&gen_a);
    prompt_b.extend([900, 901, 902, 903]);
    let eng = engine();
    let mut clock = vclock();
    let mut c = cfg(false);
    c.pool_pages = 5; // 20 rows/layer
    c.prefix_cache = true;
    let subs = vec![
        SessionSubmit::new(DecodeRequest::new(0, prompt_a.clone(), 8))
            .at(0.0),
        SessionSubmit::new(DecodeRequest::new(1, (700..708).collect(), 12))
            .at(0.5),
        SessionSubmit::new(DecodeRequest::new(2, prompt_b, 8)).at(0.55),
    ];
    let script = vec![
        ScriptedCommand::immediately(SessionAction::Submit(subs)),
        // r1's 5th token lands after r2 queued and was probed
        ScriptedCommand::after_tokens(1, 5, SessionAction::Cancel(2)),
        ScriptedCommand::immediately(SessionAction::Drain),
    ];
    let report = run_scripted(&eng, &c, &mut clock, script).unwrap();
    let r2 = report.results.iter().find(|r| r.id == 2).unwrap();
    assert_eq!(r2.status, Outcome::Cancelled);
    assert!(r2.tokens.is_empty(), "r2 must be cancelled while queued");
    let r1 = report.results.iter().find(|r| r.id == 1).unwrap();
    assert_eq!(r1.status, Outcome::Completed);
    assert_eq!(r1.tokens.len(), 12);
    assert_eq!(report.metrics.prefix_hits, 0,
               "a queued reservation is not a hit until it attaches");
    assert_eq!(report.metrics.requests_cancelled, 1);
    assert_eq!(eng.pool.lock().unwrap().stats().allocated_pages, 0,
               "queued-cancel leaked reservation-pinned pages");
}

#[test]
fn preempting_a_prefix_hit_resumes_bit_identical_and_rehits() {
    // 48-row/layer budget.  r1 (raw 61 rows, discounted 45) admits
    // only via its prefix hit and leaves 3 free rows; Interactive r2
    // (10 rows) starves behind it and evicts it.  The recompute resume
    // must re-probe the index (second hit), and r1's tokens must be
    // bit-identical to an unconstrained prefix-off run.
    let (prompt_a, gen_a) = opening_transcript();
    let mut prompt_b = prompt_a.clone();
    prompt_b.extend_from_slice(&gen_a);
    prompt_b.extend([900, 901, 902, 903]); // 21 tokens
    let run = |pool_pages: usize, prefix: bool| {
        let eng = engine();
        let mut clock = vclock();
        let mut c = cfg(true); // preempt on, starvation 2
        c.pool_pages = pool_pages;
        c.prefix_cache = prefix;
        let subs = vec![
            SessionSubmit::new(DecodeRequest::new(0, prompt_a.clone(), 8))
                .at(0.0)
                .priority(Priority::Background),
            SessionSubmit::new(DecodeRequest::new(1, prompt_b.clone(), 40))
                .at(1.0)
                .priority(Priority::Background),
            SessionSubmit::new(DecodeRequest::new(2, (800..804).collect(),
                                                  6))
                .at(1.1)
                .priority(Priority::Interactive),
        ];
        let report = run_scripted(&eng, &c, &mut clock,
                                  submit_all(subs)).unwrap();
        assert_eq!(eng.pool.lock().unwrap().stats().allocated_pages, 0,
                   "pool must drain after the session");
        report
    };
    let constrained = run(12, true);
    assert!(constrained.metrics.preemptions > 0,
            "starved r2 must evict the prefix-hit resident");
    assert_eq!(constrained.metrics.prefix_hits, 2,
               "initial attach plus recompute-resume re-attach");
    for r in &constrained.results {
        assert_eq!(r.status, Outcome::Completed);
    }
    let relaxed = run(128, false);
    assert_eq!(relaxed.metrics.preemptions, 0);
    assert_eq!(relaxed.metrics.prefix_hits, 0);
    assert_eq!(tokens_by_id(&constrained.results),
               tokens_by_id(&relaxed.results),
               "shared-page preemption broke recompute bit-identity");
}

// ---------------------------------------------------------------------
// Wrapper equivalence (serve == scripted closed-loop session)
// ---------------------------------------------------------------------

#[test]
fn closed_loop_wrapper_matches_direct_session_script() {
    // serve() is a script (submit-all-now + drain, preempt off); an
    // explicitly written equivalent script must reproduce its tokens
    let requests = || -> Vec<DecodeRequest> {
        (0..5).map(|i| DecodeRequest::new(i, vec![3 + i as u32, 7], 6))
            .collect()
    };
    let mut c = cfg(true);
    c.pool_pages = 128;
    let via_serve = {
        let eng = engine();
        let r = amla::coordinator::serve(&eng, requests(), &c).unwrap();
        tokens_by_id(&r.results)
    };
    let via_script = {
        let eng = engine();
        let mut clock = SimClock::wall();
        let mut script_cfg = c.clone();
        script_cfg.preempt = false;
        let subs = requests().into_iter().map(SessionSubmit::new).collect();
        let r = run_scripted(&eng, &script_cfg, &mut clock,
                             submit_all(subs))
            .unwrap();
        tokens_by_id(&r.results)
    };
    assert_eq!(via_serve, via_script);
}
