//! Prefix-cache serving tier: shared-prefix KV reuse must be a pure
//! scheduling optimization — `--prefix-cache on` emits **bit-identical
//! tokens** to `off` for the same conversational workload, while doing
//! strictly less prefill work and recording hits (contract 9,
//! `docs/ARCHITECTURE.md`).
//!
//! Two vehicles:
//! * a **static conversational trace** (materialized turn-by-turn from
//!   the deterministic oracle — generation is a pure function of the
//!   prompt, pinned by the cross-config identity tests) replayed by
//!   `serve_open_loop` across `fuse on/off × workers 1/4 ×
//!   prefill-chunk {1,8}`, on vs off;
//! * a **live multi-turn session** ([`AmlaEngine`]) where each
//!   follow-up is built at serve time from the previous turn's actual
//!   result ([`follow_up_request`]) — the workload the cache exists
//!   for.
//!
//! The companion cache-**bit** identity pin (a prefix hit attaches the
//! very pages a cold prefill would have written, bit-for-bit) lives in
//! `coordinator::scheduler` unit tests, where sequence caches are
//! inspectable mid-flight.

use amla::config::{Algo, EngineConfig, ServeConfig};
use amla::coordinator::{follow_up_request, serve, ConversationSpec,
                        DecodeEngine, DecodeRequest, HostLayerExecutor,
                        RequestId, TracedRequest};
use amla::numerics::mla::MlaDims;
use amla::serving::clock::{SimClock, StepCostModel};
use amla::serving::{serve_open_loop, AmlaEngine};

fn host_executor() -> HostLayerExecutor {
    let dims = MlaDims { d_model: 48, n1: 2, d_head: 12, q_rank: 24,
                         d_latent: 16, d_rope: 8, sq: 1 };
    HostLayerExecutor::new(dims, 2, Algo::Amla, 32, vec![32, 64], 11)
}

/// Real pool: 512 pages of 8 rows — the prefix index keys on this
/// physical page size.
fn engine() -> DecodeEngine<HostLayerExecutor> {
    DecodeEngine::new(host_executor(), 512, 8)
}

fn base_cfg() -> ServeConfig {
    ServeConfig { max_batch: 4, workers: 2, batch_workers: 2,
                  pool_pages: 64, page_size: 8,
                  ..ServeConfig::default() }
}

fn tokens_by_id(results: &[amla::coordinator::DecodeResult])
                -> Vec<(RequestId, Vec<u32>)> {
    let mut t: Vec<_> = results.iter()
        .map(|r| (r.id, r.tokens.clone()))
        .collect();
    t.sort_by_key(|(id, _)| *id);
    t
}

/// Materialize a 2-conversation × 3-turn trace: each follow-up turn's
/// prompt is the previous turn's full transcript plus fresh seeded
/// user tokens.  The per-turn generated tokens come from scratch
/// closed-loop runs — valid as an oracle because generation is a pure
/// function of the prompt.  Turn `t` arrives 3 virtual seconds after
/// turn `t-1` (far beyond its completion), so the previous transcript
/// is always published before the follow-up is considered.
fn conversation_trace() -> Vec<TracedRequest> {
    let spec = ConversationSpec::default(); // 3 turns
    let c = base_cfg();
    let mut trace = Vec::new();
    let mut id: RequestId = 0;
    for conv in 0..2u64 {
        let opening: Vec<u32> =
            (0..9).map(|i| 1000 * conv as u32 + 17 + i).collect();
        let mut req = DecodeRequest::new(id, opening, 8);
        for turn in 0..spec.turns {
            trace.push(TracedRequest {
                request: req.clone(),
                arrival: conv as f64 * 0.1 + turn as f64 * 3.0,
            });
            if turn + 1 == spec.turns {
                break;
            }
            let eng = engine();
            let res = serve(&eng, vec![req.clone()], &c).unwrap();
            id += 1;
            req = follow_up_request(&spec, conv, turn + 1, id,
                                    &req.prompt, &res.results[0].tokens);
        }
        id += 1;
    }
    assert_eq!(trace.len(), 6, "2 conversations x 3 turns");
    trace
}

// contract:9 prefix-hit ≡ cold-prefill bit-identity across the grid
#[test]
fn prefix_on_is_token_identical_across_the_config_grid() {
    let trace = conversation_trace();
    let mut oracle: Option<Vec<(RequestId, Vec<u32>)>> = None;
    for fuse in [false, true] {
        for workers in [1usize, 4] {
            for chunk in [1usize, 8] {
                let cell = format!(
                    "fuse={fuse} workers={workers} chunk={chunk}");
                let run = |prefix: bool| {
                    let eng = engine();
                    let mut clock = SimClock::simulated(
                        StepCostModel::new(0.01, 0.0));
                    let mut c = base_cfg();
                    c.workers = workers;
                    c.batch_workers = workers;
                    c.fuse_buckets = fuse;
                    c.prefill_chunk = chunk;
                    c.prefix_cache = prefix;
                    let report = serve_open_loop(&eng, trace.clone(), &c,
                                                 &mut clock).unwrap();
                    assert_eq!(report.results.len(), 6);
                    assert_eq!(
                        eng.pool.lock().unwrap().stats().allocated_pages,
                        0, "session teardown must drain the pool");
                    (tokens_by_id(&report.results),
                     report.metrics.prefix_hits,
                     report.metrics.prefix_hit_rows,
                     report.metrics.prompt_tokens,
                     report.metrics.prefill_chunks)
                };
                let (tok_off, hits_off, _, pt_off, pc_off) = run(false);
                let (tok_on, hits_on, hit_rows, pt_on, pc_on) = run(true);
                assert_eq!(hits_off, 0, "{cell}: off must never hit");
                assert_eq!(hits_on, 4,
                           "{cell}: every follow-up (2 convs x 2) hits");
                assert!(hit_rows >= 4 * 8,
                        "{cell}: each hit attaches >= 1 whole page");
                assert_eq!(tok_on, tok_off,
                           "{cell}: prefix cache changed served tokens");
                assert!(pt_on < pt_off,
                        "{cell}: hits must skip prompt rows \
                         ({pt_on} vs {pt_off})");
                assert!(pc_on < pc_off,
                        "{cell}: hits must save prefill invocations \
                         ({pc_on} vs {pc_off})");
                match &oracle {
                    Some(o) => assert_eq!(&tok_on, o,
                        "{cell}: diverged from the reference cell"),
                    None => oracle = Some(tok_off),
                }
            }
        }
    }
}

/// Drive true serve-time conversations through the live engine: each
/// follow-up is constructed from the previous turn's **actual** result.
fn run_live_conversations(prefix: bool)
    -> (Vec<(RequestId, Vec<u32>)>, amla::coordinator::Metrics) {
    let cfg = EngineConfig::builder()
        .pool_pages(64)
        .page_size(8)
        .max_batch(4)
        .batch_workers(2)
        .preempt(false)
        .prefix_cache(prefix)
        .build()
        .unwrap();
    let engine = AmlaEngine::start(cfg, host_executor()).unwrap();
    let spec = ConversationSpec::default();
    let mut out = Vec::new();
    let mut id: RequestId = 0;
    for conv in 0..2u64 {
        let opening: Vec<u32> =
            (0..9).map(|i| 1000 * conv as u32 + 17 + i).collect();
        let mut req = DecodeRequest::new(id, opening, 8);
        for turn in 0..spec.turns {
            let res = engine.submit(req.clone()).unwrap().wait().unwrap();
            out.push((res.id, res.tokens.clone()));
            if turn + 1 == spec.turns {
                break;
            }
            id += 1;
            req = follow_up_request(&spec, conv, turn + 1, id,
                                    &req.prompt, &res.tokens);
        }
        id += 1;
    }
    let report = engine.shutdown().unwrap();
    out.sort_by_key(|(id, _)| *id);
    (out, report.metrics)
}

#[test]
fn live_multi_turn_session_hits_without_changing_tokens() {
    let (tok_on, m_on) = run_live_conversations(true);
    let (tok_off, m_off) = run_live_conversations(false);
    assert_eq!(tok_on, tok_off,
               "--prefix-cache on changed a live conversation's tokens");
    assert_eq!(m_off.prefix_hits, 0);
    assert_eq!(m_on.prefix_hits, 4, "every follow-up must hit");
    assert!(m_on.prefix_hit_rows >= 4 * 8);
    assert!(m_on.prompt_tokens < m_off.prompt_tokens,
            "hits must reduce prompt rows fed");
    assert!(m_on.prefill_chunks < m_off.prefill_chunks,
            "hits must reduce prefill invocations \
             ({} vs {})", m_on.prefill_chunks, m_off.prefill_chunks);
    assert_eq!(m_on.requests_completed, 6);
}
