//! Tier-1 gate: the committed tree must satisfy every `amla-lint`
//! invariant — determinism markers audited, add-only regions intact
//! over the rescale core, SAFETY/panic justifications present, no
//! unaudited `#[allow(...)]`, and `docs/api_surface.txt` in sync —
//! plus every `amla-audit` flow-aware pass (interprocedural add-only
//! purity, Δn clamp intervals, blocking-under-lock + lock-order,
//! contract coverage), so `cargo test -q` runs both checkers on every
//! push.

use std::path::Path;

#[test]
fn lint_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = amla::analysis::lint_repo(root)
        .expect("lint walk over rust/src failed");
    assert!(findings.is_empty(),
            "amla-lint found {} violation(s):\n{}",
            findings.len(),
            findings.iter().map(ToString::to_string)
                .collect::<Vec<_>>().join("\n"));
}

#[test]
fn audit_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = amla::analysis::audit_repo(root)
        .expect("audit walk over rust/src + rust/tests failed");
    assert!(findings.is_empty(),
            "amla-audit found {} violation(s):\n{}",
            findings.len(),
            findings.iter().map(ToString::to_string)
                .collect::<Vec<_>>().join("\n"));
}
