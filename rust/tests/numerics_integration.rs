//! Cross-module numerics integration: the full-protocol accuracy tables
//! at reduced sample count, Base/AMLA/golden triangulation, and the MLA
//! layer driven through every attention backend.

use amla::numerics::flash_base::{base_flash_attention, FlashConfig};
use amla::numerics::golden::{golden_attention, row_limits};
use amla::numerics::amla::amla_attention;
use amla::numerics::mla::{decode_step_with, MlaDims, MlaWeights};
use amla::numerics::{rel_frobenius_error, Matrix, Rng};
use amla::report::accuracy_row;

#[test]
fn tables_3_and_4_reduced_protocol() {
    // paper: both methods ~1e-3..1e-4, indistinguishable from each other
    for (dist, param) in [("normal", 1.0), ("normal", 4.0),
                          ("uniform", 1.0), ("uniform", 10.0)] {
        let (base, amla_err) = accuracy_row(dist, param, 3, 2048, 16);
        assert!(base < 8e-3, "{dist}({param}) base {base}");
        assert!(amla_err < 8e-3, "{dist}({param}) amla {amla_err}");
        assert!((amla_err - base).abs() <= 0.2 * base + 1e-5,
                "{dist}({param}): amla {amla_err} vs base {base}");
    }
}

#[test]
fn error_decreases_with_wider_uniform_range() {
    // paper Table 4: error *decreases* as the range widens (softmax
    // concentrates); verify the trend
    let (_, e1) = accuracy_row("uniform", 1.0, 3, 1024, 8);
    let (_, e60) = accuracy_row("uniform", 60.0, 3, 1024, 8);
    assert!(e60 < e1, "expected monotone decrease: {e1} -> {e60}");
}

#[test]
fn layer_consistent_across_attention_backends() {
    let dims = MlaDims { d_model: 128, n1: 4, d_head: 32, q_rank: 64,
                         d_latent: 48, d_rope: 16, sq: 1 };
    let w = MlaWeights::init(dims, 3);
    let mut rng = Rng::new(4);
    let s2 = 128;
    let x: Vec<f32> = (0..dims.d_model).map(|_| rng.gaussian()).collect();

    let mut outs: Vec<Vec<f32>> = Vec::new();
    for algo in ["golden", "base", "amla"] {
        let mut c = rng.clone().gaussian_matrix(s2, dims.d_latent, 0.1);
        let mut kr = rng.clone().gaussian_matrix(s2, dims.d_rope, 0.1);
        let y = decode_step_with(&x, &mut c, &mut kr, 100, &w,
            |q, k, v, valid| match algo {
                "golden" => {
                    let limits = row_limits(q.rows, dims.n1, dims.sq, valid);
                    golden_attention(q, k, v, &limits)
                }
                name => {
                    let cfg = FlashConfig { block_kv: 64, n1: dims.n1,
                                            sq: dims.sq, valid_len: valid,
                                            mixed_bf16: false };
                    if name == "base" {
                        base_flash_attention(q, k, v, &cfg)
                    } else {
                        amla_attention(q, k, v, &cfg)
                    }
                }
            });
        outs.push(y);
    }
    assert!(rel_frobenius_error(&outs[1], &outs[0]) < 1e-5, "base vs golden");
    assert!(rel_frobenius_error(&outs[2], &outs[0]) < 1e-5, "amla vs golden");
}

#[test]
fn amla_base_agree_at_paper_shape() {
    // one full paper-shaped head group (G=128, Dk=576, Dv=512, 2K ctx)
    let mut rng = Rng::new(9);
    let q = rng.gaussian_matrix(128, 576, 1.0);
    let k = rng.gaussian_matrix(2048, 576, 1.0);
    let v = rng.gaussian_matrix(2048, 512, 1.0);
    let cfg = FlashConfig { block_kv: 512, n1: 128, sq: 1, valid_len: 2048,
                            mixed_bf16: true };
    let a = amla_attention(&q, &k, &v, &cfg);
    let b = base_flash_attention(&q, &k, &v, &cfg);
    let gold = golden_attention(&q, &k, &v, &row_limits(128, 128, 1, 2048));
    let ea = rel_frobenius_error(&a.data, &gold.data);
    let eb = rel_frobenius_error(&b.data, &gold.data);
    assert!(ea < 8e-3 && eb < 8e-3);
    assert!((ea - eb).abs() < 0.15 * eb, "amla {ea} base {eb}");
}

#[test]
fn valid_len_sweep_against_prefix_golden() {
    let mut rng = Rng::new(10);
    let q = rng.gaussian_matrix(8, 128, 1.0);
    let k = rng.gaussian_matrix(512, 128, 1.0);
    let v = rng.gaussian_matrix(512, 64, 1.0);
    for valid in [1, 63, 64, 65, 250, 512] {
        let cfg = FlashConfig { block_kv: 64, n1: 8, sq: 1,
                                valid_len: valid, mixed_bf16: false };
        let out = amla_attention(&q, &k, &v, &cfg);
        let kp = Matrix::from_vec(valid, 128, k.data[..valid * 128].to_vec());
        let vp = Matrix::from_vec(valid, 64, v.data[..valid * 64].to_vec());
        let gold = golden_attention(&q, &kp, &vp, &vec![valid; 8]);
        assert!(rel_frobenius_error(&out.data, &gold.data) < 1e-4,
                "valid={valid}");
    }
}
