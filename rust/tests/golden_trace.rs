//! Golden-trace regression: one deterministic 4-sequence mixed-bucket
//! decode trace — every emitted token plus the final step's
//! residual-stream bits — must be reproduced **exactly** by every
//! serving configuration (`fuse on/off × workers 1/4/8 × split-KV
//! flash decoding off/on`), and must match
//! the committed golden file so future kernel rewrites cannot silently
//! drift the numerics.
//!
//! Bootstrap: if `rust/tests/golden/decode_trace.txt` is missing (or
//! `AMLA_REGEN_GOLDEN=1` is set) the test writes it from the current
//! build and reports success — commit the generated file to arm the
//! cross-PR pin.  The cross-config identity assertions always run.

use amla::config::Algo;
use amla::coordinator::engine::{HostLayerExecutor, SeqRuntime};
use amla::coordinator::DecodeEngine;
use amla::numerics::mla::MlaDims;
use amla::testing::{decode_f32_bits, drive_prompts, encode_f32_bits};

const GOLDEN_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"),
                                  "/rust/tests/golden/decode_trace.txt");
const DECODE_STEPS: usize = 8;

/// Prompts chosen so the batch spans both KV buckets mid-trace: seq 1
/// crosses from the 64 into the 128 bucket while the others stay in 64,
/// exercising fused groups, singleton fallback, and regrouping.
fn prompts() -> Vec<Vec<u32>> {
    vec![
        vec![11, 12, 13],
        vec![7; 60],
        vec![5, 6],
        vec![9; 30],
    ]
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct Trace {
    /// Per sequence: every token emitted (prompt phase + decode phase).
    tokens: Vec<Vec<u32>>,
    /// Per sequence: bit pattern of the final step's residual stream.
    xbits: Vec<Vec<u32>>,
}

fn run_trace(fuse: bool, workers: usize, split_kv: usize) -> Trace {
    let dims = MlaDims { d_model: 64, n1: 2, d_head: 16, q_rank: 32,
                         d_latent: 24, d_rope: 8, sq: 1 };
    let exec = HostLayerExecutor::new(dims, 2, Algo::Amla, 32,
                                      vec![64, 128], 7)
        .with_fuse(fuse)
        .with_split_kv(split_kv);
    let eng = DecodeEngine::new(exec, 1024, 16);
    let prompts = prompts();
    let n = prompts.len();
    let mut rts: Vec<SeqRuntime> =
        (0..n).map(|_| SeqRuntime::new(2)).collect();

    // prompt phase: one prompt token per global step, like the serve
    // loop (the shared driver in amla::testing)
    let mut tokens = drive_prompts(&eng, &mut rts, &prompts, workers);
    let mut last: Vec<u32> =
        tokens.iter().map(|t| *t.last().expect("non-empty prompt")).collect();

    // decode phase: the whole batch steps together; the final step is
    // traced so the residual-stream bits are pinned too
    let mut xbits: Vec<Vec<u32>> = vec![Vec::new(); n];
    for step in 0..DECODE_STEPS {
        let feeds = last.clone();
        if step + 1 < DECODE_STEPS {
            let outs = eng.step_batch(&mut rts, &feeds, workers);
            for (i, o) in outs.into_iter().enumerate() {
                let t = o.expect("decode step failed");
                tokens[i].push(t);
                last[i] = t;
            }
        } else {
            let outs = eng.step_batch_traced(&mut rts, &feeds, workers);
            for (i, o) in outs.into_iter().enumerate() {
                let tr = o.expect("traced decode step failed");
                tokens[i].push(tr.token);
                xbits[i] = tr.x.iter().map(|x| x.to_bits()).collect();
            }
        }
    }
    Trace { tokens, xbits }
}

/// Render the comparable body of the golden file (no comment lines).
fn render(trace: &Trace) -> String {
    let mut out = String::new();
    for i in 0..trace.tokens.len() {
        out.push_str(&format!("seq {i}\n"));
        let toks: Vec<String> =
            trace.tokens[i].iter().map(u32::to_string).collect();
        out.push_str(&format!("tokens {}\n", toks.join(" ")));
        let x: Vec<f32> =
            trace.xbits[i].iter().map(|&b| f32::from_bits(b)).collect();
        out.push_str(&format!("xbits {}\n", encode_f32_bits(&x)));
    }
    out
}

fn parse(text: &str) -> Option<Trace> {
    let mut tokens = Vec::new();
    let mut xbits = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(rest) = line.strip_prefix("tokens ") {
            tokens.push(rest.split_whitespace()
                .map(|t| t.parse::<u32>().ok())
                .collect::<Option<Vec<u32>>>()?);
        } else if let Some(rest) = line.strip_prefix("xbits ") {
            xbits.push(decode_f32_bits(rest)?
                .iter().map(|x| x.to_bits()).collect());
        } else if !line.starts_with("seq ") {
            return None;
        }
    }
    if tokens.is_empty() || tokens.len() != xbits.len() {
        return None;
    }
    Some(Trace { tokens, xbits })
}

// contract:1 fused-kernel bit-identity across the fuse/worker/split grid
#[test]
fn golden_trace_reproduces_across_all_configs() {
    // unfused serial, split-KV off = the oracle
    let reference = run_trace(false, 1, 0);
    // the split-KV axis: threshold 16 forces the flash-decoding route
    // as soon as a sequence's context crosses 16 rows.  workers=1 keeps
    // split_parts=1 (the policy never splits without spare slots),
    // workers=8 against the 4-sequence batch leaves 5 spare slots, so
    // sequences split into 2 (64-row bucket) and up to 4 (128-row
    // bucket) partitions — all of it must be bit-identical to the
    // serial single-pass trace (the frame-replay contract).
    for (fuse, workers, split_kv) in [(false, 4, 0), (true, 1, 0),
                                      (true, 4, 0), (false, 1, 16),
                                      (false, 8, 16), (true, 8, 16)] {
        let got = run_trace(fuse, workers, split_kv);
        assert_eq!(got, reference,
                   "fuse={fuse} workers={workers} split_kv={split_kv} \
                    diverged from the unfused serial trace");
    }

    let path = std::path::Path::new(GOLDEN_PATH);
    let regen = std::env::var("AMLA_REGEN_GOLDEN").is_ok();
    if path.exists() && !regen {
        let text = std::fs::read_to_string(path).expect("read golden file");
        let golden = parse(&text).expect("malformed golden file — \
            regenerate with AMLA_REGEN_GOLDEN=1");
        assert_eq!(reference, golden,
                   "decode trace drifted from {GOLDEN_PATH}; if the \
                    change is intended, regenerate with \
                    AMLA_REGEN_GOLDEN=1 cargo test --test golden_trace \
                    and commit the diff");
    } else {
        let header = "\
# AMLA golden decode trace v1 (4 sequences, mixed 64/128 buckets,\n\
# 2-layer host model, bf16 kernels).  Pinned bit-for-bit by\n\
# rust/tests/golden_trace.rs across fuse on/off x workers 1/4/8\n\
# x split-KV flash decoding off/on (threshold 16).\n\
# Regenerate: AMLA_REGEN_GOLDEN=1 cargo test --test golden_trace\n";
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).expect("create golden dir");
        }
        std::fs::write(path, format!("{header}{}", render(&reference)))
            .expect("write golden file");
        eprintln!("golden trace written to {GOLDEN_PATH}; commit it to \
                   arm the cross-PR regression pin");
    }
}

#[test]
fn golden_file_roundtrips_through_parser() {
    // the serializer and parser must agree, so a committed file cannot
    // be misread as matching when it does not
    let tr = Trace {
        tokens: vec![vec![1, 2, 3], vec![9]],
        xbits: vec![vec![0x3F800000, 0x80000000], vec![0x7F7FFFFF]],
    };
    let parsed = parse(&render(&tr)).expect("roundtrip parse");
    assert_eq!(tr, parsed);
    assert!(parse("garbage\n").is_none());
}
