//! Integration: PJRT runtime against the real AOT artifacts.
//!
//! Requires `make artifacts` (skips cleanly otherwise).  Verifies that
//! the HLO text compiled by `python/compile/aot.py` loads on the CPU
//! PJRT client and computes the same attention as the bit-exact Rust
//! numerics / golden oracle.

use amla::numerics::flash_base::FlashConfig;
use amla::numerics::golden::{golden_attention, row_limits};
use amla::numerics::{rel_frobenius_error, Matrix, Rng};
use amla::runtime::{Engine, TensorView};

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::new("artifacts").expect("engine"))
}

fn make_qkv(rng: &mut Rng, g: usize, s2: usize) -> (Matrix, Matrix, Matrix) {
    (rng.gaussian_matrix(g, 576, 1.0), rng.gaussian_matrix(s2, 576, 1.0),
     rng.gaussian_matrix(s2, 512, 1.0))
}

fn run_kernel(engine: &Engine, algo: &str, n1: usize, sq: usize,
              kv_len: usize, q: &Matrix, k: &Matrix, v: &Matrix) -> Vec<f32> {
    let kernel = engine.load_kernel_for(algo, n1, sq, kv_len).expect("load");
    let meta = &kernel.meta;
    let bucket = meta.bucket;
    assert_eq!(k.rows, bucket, "caller must pad to the bucket");
    let valid = [kv_len as i32];
    let g = n1 * sq;
    let out = kernel
        .run(&[
            TensorView::F32(&q.data, &[g, 576]),
            TensorView::F32(&k.data, &[bucket, 576]),
            TensorView::F32(&v.data, &[bucket, 512]),
            TensorView::I32(&valid, &[1]),
        ])
        .expect("run");
    out.into_iter().next().unwrap()
}

#[test]
fn amla_artifact_matches_golden() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(1);
    let (n1, sq, kv) = (16, 1, 256);
    let (q, k, v) = make_qkv(&mut rng, n1 * sq, 256);
    let out = run_kernel(&engine, "amla", n1, sq, kv, &q, &k, &v);
    let gold = golden_attention(&q, &k, &v, &row_limits(n1, n1, 1, kv));
    let err = rel_frobenius_error(&out, &gold.data);
    // artifact runs BF16 matmuls inside
    assert!(err < 1e-2, "amla artifact vs golden: {err}");
}

#[test]
fn base_artifact_matches_golden() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(2);
    let (n1, sq, kv) = (16, 1, 256);
    let (q, k, v) = make_qkv(&mut rng, n1 * sq, 256);
    let out = run_kernel(&engine, "base", n1, sq, kv, &q, &k, &v);
    let gold = golden_attention(&q, &k, &v, &row_limits(n1, n1, 1, kv));
    assert!(rel_frobenius_error(&out, &gold.data) < 1e-2);
}

#[test]
fn amla_artifact_tracks_rust_amla() {
    // PJRT AMLA and the Rust recurrence implement the same algorithm;
    // both in mixed BF16, so they agree to BF16 noise.
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(3);
    let (n1, sq, kv) = (16, 1, 256);
    let (q, k, v) = make_qkv(&mut rng, n1 * sq, 256);
    let out = run_kernel(&engine, "amla", n1, sq, kv, &q, &k, &v);
    let cfg = FlashConfig { block_kv: 256, n1, sq, valid_len: kv,
                            mixed_bf16: true };
    let rust = amla::numerics::amla::amla_attention(&q, &k, &v, &cfg);
    let err = rel_frobenius_error(&out, &rust.data);
    assert!(err < 5e-3, "pjrt vs rust amla: {err}");
}

#[test]
fn bucket_padding_respected() {
    // valid_len < bucket: padding rows must not influence the output
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(4);
    let (n1, sq) = (16, 1);
    let (q, mut k, mut v) = make_qkv(&mut rng, n1 * sq, 256);
    let valid = 100;
    let out1 = run_kernel(&engine, "amla", n1, sq, valid, &q, &k, &v);
    // poison the padding region
    for x in &mut k.data[valid * 576..] {
        *x = 1e4;
    }
    for x in &mut v.data[valid * 512..] {
        *x = -1e4;
    }
    let out2 = run_kernel(&engine, "amla", n1, sq, valid, &q, &k, &v);
    assert_eq!(out1, out2, "padding leaked into the output");
}

#[test]
fn mtp_artifact_is_causal() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(5);
    let (n1, sq, kv) = (16, 2, 200);
    let (q, k, v) = make_qkv(&mut rng, n1 * sq, 256);
    let out = run_kernel(&engine, "amla", n1, sq, kv, &q, &k, &v);
    // q_pos 0 rows see kv-1 tokens, q_pos 1 rows see kv
    let gold = golden_attention(&q, &k, &v, &row_limits(n1 * sq, n1, sq, kv));
    assert!(rel_frobenius_error(&out, &gold.data) < 1e-2);
}

#[test]
fn bucket_selection_picks_smallest() {
    let Some(engine) = engine() else { return };
    let reg = engine.registry();
    let buckets = reg.kernel_buckets("amla", 16, 1);
    assert!(buckets.len() >= 2, "need multiple buckets: {buckets:?}");
    let small = reg.select_kernel("amla", 16, 1, buckets[0]).unwrap();
    assert_eq!(small.bucket, buckets[0]);
    let next = reg.select_kernel("amla", 16, 1, buckets[0] + 1).unwrap();
    assert_eq!(next.bucket, buckets[1]);
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(engine) = engine() else { return };
    let a = engine.load_kernel_for("amla", 16, 1, 128).unwrap();
    let b = engine.load_kernel_for("amla", 16, 1, 200).unwrap();
    // same bucket -> same Arc
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}

#[test]
fn paper_shape_artifact_runs() {
    // N1=128 paper configuration (quickstart validation artifact)
    let Some(engine) = engine() else { return };
    if engine.registry().kernel_buckets("amla", 128, 1).is_empty() {
        eprintln!("skipping: paper-shape artifacts not built");
        return;
    }
    let mut rng = Rng::new(6);
    let (q, k, v) = make_qkv(&mut rng, 128, 1024);
    let out = run_kernel(&engine, "amla", 128, 1, 1024, &q, &k, &v);
    let gold = golden_attention(&q, &k, &v, &row_limits(128, 128, 1, 1024));
    assert!(rel_frobenius_error(&out, &gold.data) < 1e-2);
}
