//! Open-loop golden trace: one deterministic virtual-clock run — every
//! request's (merged) token stream plus the completion order — must be
//! reproduced **exactly** across `workers ∈ {1,4} × fuse on/off ×
//! preempt on/off`, and must match the committed golden file so future
//! scheduler/kernel rewrites cannot silently drift open-loop behavior.
//!
//! The trace is pool-constrained so preemption actually fires when
//! enabled: a starved small request evicts the longest resident, which
//! resumes by recompute.  Per the recompute bit-identity contract
//! (`amla::serving` docs), the preempt-on and preempt-off runs must
//! emit **identical per-request tokens** (only the completion order and
//! schedule may differ), and preempt-off must reproduce the closed-loop
//! tokens for the same request set.
//!
//! Bootstrap: if `rust/tests/golden/open_loop_trace.txt` is missing (or
//! `AMLA_REGEN_GOLDEN=1` is set) the test writes it from the current
//! build and reports success — commit the generated file to arm the
//! cross-PR pin.  The cross-config identity assertions always run.

use amla::config::{Algo, ServeConfig};
use amla::coordinator::engine::HostLayerExecutor;
use amla::coordinator::{serve, DecodeEngine, DecodeRequest, RequestId,
                        TracedRequest};
use amla::numerics::mla::MlaDims;
use amla::serving::clock::{SimClock, StepCostModel};
use amla::serving::serve_open_loop;

const GOLDEN_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"),
                                  "/rust/tests/golden/open_loop_trace.txt");

fn engine() -> DecodeEngine<HostLayerExecutor> {
    let dims = MlaDims { d_model: 64, n1: 2, d_head: 16, q_rank: 32,
                         d_latent: 24, d_rope: 8, sq: 1 };
    let exec = HostLayerExecutor::new(dims, 2, Algo::Amla, 32,
                                      vec![64, 128], 7);
    DecodeEngine::new(exec, 1024, 16)
}

/// 100-row/layer budget: r0 (27 rows) + r1 (70 rows, crosses into the
/// 128 bucket at context 65) fill it at t = 0; r2 (6 rows) arrives at
/// t = 0.08 and starves behind them, which with preemption on evicts r1
/// — by then a few tokens into *decode*, so the recompute resume path
/// replays prompt ⧺ generated — and r1 resumes once r0 drains.  r3
/// flows through the busy pool at t = 0.5; r4 arrives at t = 1.2 after
/// the engine idles, exercising the clock's idle jump.
fn trace() -> Vec<TracedRequest> {
    let mk = |id, prompt: Vec<u32>, gen, arrival| TracedRequest {
        request: DecodeRequest::new(id, prompt, gen),
        arrival,
    };
    vec![
        mk(0, vec![11, 12, 13], 24, 0.0),
        mk(1, vec![7; 10], 60, 0.0),
        mk(2, vec![5, 6], 4, 0.08),
        mk(3, vec![9; 30], 8, 0.5),
        mk(4, vec![2, 3], 6, 1.2),
    ]
}

fn cfg(workers: usize, fuse: bool, preempt: bool) -> ServeConfig {
    ServeConfig { max_batch: 4, workers, batch_workers: workers,
                  fuse_buckets: fuse,
                  pool_pages: 50, page_size: 4, // 100 rows/layer budget
                  starvation_steps: 4, preempt,
                  // the golden schedule (step counts, virtual times) is
                  // pinned at the legacy token-per-step prefill; the
                  // chunked rerun below asserts tokens separately
                  prefill_chunk: 1,
                  ..ServeConfig::default() }
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct Trace {
    /// Per request id (ascending): the merged generated token stream.
    tokens: Vec<Vec<u32>>,
    /// Request ids in completion order.
    order: Vec<RequestId>,
}

fn run_open(workers: usize, fuse: bool, preempt: bool)
            -> (Trace, u64, u64) {
    let eng = engine();
    let mut clock = SimClock::simulated(StepCostModel::new(0.01, 0.0));
    let report = serve_open_loop(&eng, trace(), &cfg(workers, fuse, preempt),
                                 &mut clock)
        .expect("open-loop serve failed");
    assert_eq!(report.results.len(), 5, "all requests must complete");
    assert_eq!(eng.pool.lock().unwrap().stats().allocated_pages, 0,
               "pages leaked");
    let mut by_id: Vec<(RequestId, Vec<u32>)> = report.results.iter()
        .map(|r| (r.id, r.tokens.clone()))
        .collect();
    by_id.sort_by_key(|(id, _)| *id);
    let tokens = by_id.into_iter().map(|(_, t)| t).collect();
    (Trace { tokens, order: report.completion_order },
     report.metrics.preemptions, report.makespan.to_bits())
}

/// Render the comparable body of the golden file (no comment lines).
fn render(off: &Trace, on: &Trace) -> String {
    let mut out = String::new();
    for (mode, tr) in [("preempt_off", off), ("preempt_on", on)] {
        out.push_str(&format!("mode {mode}\n"));
        let order: Vec<String> =
            tr.order.iter().map(u64::to_string).collect();
        out.push_str(&format!("order {}\n", order.join(" ")));
        for (i, toks) in tr.tokens.iter().enumerate() {
            let toks: Vec<String> = toks.iter().map(u32::to_string).collect();
            out.push_str(&format!("seq {i}\ntokens {}\n", toks.join(" ")));
        }
    }
    out
}

fn parse(text: &str) -> Option<(Trace, Trace)> {
    let mut traces: Vec<Trace> = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line.starts_with("mode ") {
            traces.push(Trace { tokens: Vec::new(), order: Vec::new() });
        } else if let Some(rest) = line.strip_prefix("order ") {
            traces.last_mut()?.order = rest.split_whitespace()
                .map(|t| t.parse::<u64>().ok())
                .collect::<Option<Vec<_>>>()?;
        } else if let Some(rest) = line.strip_prefix("tokens ") {
            traces.last_mut()?.tokens.push(rest.split_whitespace()
                .map(|t| t.parse::<u32>().ok())
                .collect::<Option<Vec<_>>>()?);
        } else if !line.starts_with("seq ") {
            return None;
        }
    }
    if traces.len() != 2 || traces.iter().any(|t| t.tokens.is_empty()) {
        return None;
    }
    let on = traces.pop()?;
    let off = traces.pop()?;
    Some((off, on))
}

// contract:3,4 preemption-resume bit-identity + virtual-clock
// determinism, pinned against the committed open-loop golden
#[test]
fn open_loop_golden_reproduces_across_all_configs() {
    // determinism: for each preempt setting, the unfused serial run is
    // the oracle every (workers, fuse) cell must match bit-for-bit —
    // including the virtual-time makespan
    let (reference_off, pre_off, makespan_off) = run_open(1, false, false);
    let (reference_on, pre_on, makespan_on) = run_open(1, false, true);
    assert_eq!(pre_off, 0, "preempt off must never evict");
    assert!(pre_on > 0, "the constrained trace must trigger eviction");
    for (workers, fuse) in [(1usize, true), (4, false), (4, true)] {
        let got_off = run_open(workers, fuse, false);
        assert_eq!(got_off, (reference_off.clone(), pre_off, makespan_off),
                   "preempt=off workers={workers} fuse={fuse} diverged");
        let got_on = run_open(workers, fuse, true);
        assert_eq!(got_on, (reference_on.clone(), pre_on, makespan_on),
                   "preempt=on workers={workers} fuse={fuse} diverged");
    }

    // recompute bit-identity: eviction + resume must not change any
    // request's token stream (only scheduling may differ)
    assert_eq!(reference_on.tokens, reference_off.tokens,
               "preemption changed token streams");

    // preempt off must reproduce the closed-loop tokens for the same
    // request set (the open loop is an admission policy, not a fork)
    let closed = {
        let eng = engine();
        let requests: Vec<DecodeRequest> =
            trace().into_iter().map(|t| t.request).collect();
        let report = serve(&eng, requests, &cfg(4, true, false))
            .expect("closed-loop serve failed");
        let mut by_id: Vec<(RequestId, Vec<u32>)> = report.results.iter()
            .map(|r| (r.id, r.tokens.clone()))
            .collect();
        by_id.sort_by_key(|(id, _)| *id);
        by_id.into_iter().map(|(_, t)| t).collect::<Vec<_>>()
    };
    assert_eq!(reference_off.tokens, closed,
               "open-loop (preempt off) diverged from closed-loop tokens");

    // golden-file pin (bootstraps on first toolchain run — commit it)
    let path = std::path::Path::new(GOLDEN_PATH);
    let regen = std::env::var("AMLA_REGEN_GOLDEN").is_ok();
    if path.exists() && !regen {
        let text = std::fs::read_to_string(path).expect("read golden file");
        let (golden_off, golden_on) = parse(&text)
            .expect("malformed golden file — regenerate with \
                     AMLA_REGEN_GOLDEN=1");
        assert_eq!((reference_off, reference_on), (golden_off, golden_on),
                   "open-loop trace drifted from {GOLDEN_PATH}; if the \
                    change is intended, regenerate with \
                    AMLA_REGEN_GOLDEN=1 cargo test --test \
                    open_loop_golden and commit the diff");
    } else {
        let header = "\
# AMLA golden open-loop trace v1 (5 requests, 100-row pool budget,\n\
# virtual clock 10ms/step, starvation 4 steps; preempt off vs on).\n\
# Pinned bit-for-bit by rust/tests/open_loop_golden.rs across\n\
# workers 1/4 x fuse on/off; per-request tokens must also be\n\
# identical across the two preempt modes (recompute bit-identity).\n\
# Regenerate: AMLA_REGEN_GOLDEN=1 cargo test --test open_loop_golden\n";
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).expect("create golden dir");
        }
        std::fs::write(path,
                       format!("{header}{}",
                               render(&reference_off, &reference_on)))
            .expect("write golden file");
        eprintln!("open-loop golden trace written to {GOLDEN_PATH}; commit \
                   it to arm the cross-PR regression pin");
    }
}

// contract:2 chunked-prefill bit-identity against the one-shot path
#[test]
fn chunked_prefill_reproduces_golden_tokens() {
    // chunked prefill (the default serving path) reschedules prefill
    // but must never change what is generated: per-request token
    // streams at prefill_chunk 3 must equal the chunk=1 golden
    // reference for both preempt settings — chunked recompute-resume
    // included — while taking strictly fewer prefill invocations
    for preempt in [false, true] {
        let (reference, _, _) = run_open(1, false, preempt);
        let eng = engine();
        let mut clock = SimClock::simulated(StepCostModel::new(0.01, 0.0));
        let mut c = cfg(4, true, preempt);
        c.prefill_chunk = 3;
        let report = serve_open_loop(&eng, trace(), &c, &mut clock)
            .expect("chunked open-loop serve failed");
        let mut by_id: Vec<(RequestId, Vec<u32>)> = report.results.iter()
            .map(|r| (r.id, r.tokens.clone()))
            .collect();
        by_id.sort_by_key(|(id, _)| *id);
        let tokens: Vec<Vec<u32>> =
            by_id.into_iter().map(|(_, t)| t).collect();
        assert_eq!(tokens, reference.tokens,
                   "preempt={preempt}: chunked prefill changed tokens");
        assert!(report.metrics.prefill_chunks
                    < report.metrics.prompt_tokens,
                "preempt={preempt}: chunking did not reduce prefill \
                 invocations ({} chunks for {} prompt tokens)",
                report.metrics.prefill_chunks,
                report.metrics.prompt_tokens);
    }
}

#[test]
fn golden_file_roundtrips_through_parser() {
    let off = Trace { tokens: vec![vec![1, 2], vec![3]], order: vec![1, 0] };
    let on = Trace { tokens: vec![vec![1, 2], vec![3]], order: vec![0, 1] };
    let (p_off, p_on) = parse(&render(&off, &on)).expect("roundtrip parse");
    assert_eq!((p_off, p_on), (off, on));
    assert!(parse("garbage\n").is_none());
    assert!(parse("mode only\n").is_none());
}
