//! Chaos scenario tier: the engine must *survive* adversarial traffic —
//! flash crowds, cancel storms, slow-consumer floods, long-context +
//! chat mixes, pool churn — and survive it **deterministically**.
//!
//! Contract 10 (`docs/ARCHITECTURE.md`), pinned here end to end:
//!
//! 1. every request served under chaos emits tokens bit-identical to an
//!    unloaded run of that request alone;
//! 2. shedding / degradation / aging decisions are a pure function of
//!    `(seed, config)` — byte-identical across `--batch-workers 1/4`
//!    and fuse on/off;
//! 3. pool pages and admission budget return exactly to zero after the
//!    storm (proven black-box: a follow-up request sized to the *whole*
//!    pool budget must admit and complete).
//!
//! Every scenario runs with `--prefix-cache on` and
//! `--split-kv-threshold 16` (the acceptance matrix), on the seeded
//! virtual clock.

use amla::config::{Algo, ServeConfig, ShedPolicy};
use amla::coordinator::engine::HostLayerExecutor;
use amla::coordinator::{DecodeEngine, DecodeRequest, DecodeResult,
                        LenDist, Outcome, Priority, RequestId};
use amla::numerics::mla::MlaDims;
use amla::serving::clock::{SimClock, StepCostModel};
use amla::serving::{cancel_storm, chaos_sweep, diverged_from_unloaded,
                    flash_crowd, long_context_mix, pool_churn,
                    repeat_evict_crowd, run_chaos, run_scripted,
                    slow_consumer_flood, CancelStormSpec, ChaosSweepConfig,
                    EngineReport, FlashCrowdSpec, LongContextMixSpec,
                    PoolChurnSpec, RepeatEvictSpec, ScriptedCommand,
                    SessionAction, SessionSubmit, SPIKE_ID_BASE, VICTIM_ID};
use amla::util::json::Json;

fn engine() -> DecodeEngine<HostLayerExecutor> {
    let dims = MlaDims { d_model: 48, n1: 2, d_head: 12, q_rank: 24,
                         d_latent: 16, d_rope: 8, sq: 1 };
    let exec = HostLayerExecutor::new(dims, 2, Algo::Amla, 32,
                                      vec![32, 64], 11);
    DecodeEngine::new(exec, 512, 8)
}

fn model() -> StepCostModel {
    StepCostModel::new(0.01, 0.0)
}

/// The acceptance-matrix base config: prefix cache ON, split-KV
/// threshold 16, preemption on.  `pool_pages` shapes the admission
/// budget: rows/layer = pool_pages × page_size / n_layers = 4 × pages.
fn cfg(pool_pages: usize, workers: usize, fuse: bool) -> ServeConfig {
    ServeConfig { max_batch: 4, workers, batch_workers: workers,
                  fuse_buckets: fuse, pool_pages, page_size: 8,
                  preempt: true, starvation_steps: 4,
                  prefix_cache: true, split_kv_threshold: 16,
                  ..ServeConfig::default() }
}

fn tokens_by_id(results: &[DecodeResult]) -> Vec<(RequestId, Vec<u32>)> {
    let mut t: Vec<_> = results.iter()
        .map(|r| (r.id, r.tokens.clone()))
        .collect();
    t.sort_by_key(|(id, _)| *id);
    t
}

fn assert_pool_drained(eng: &DecodeEngine<HostLayerExecutor>, tag: &str) {
    assert_eq!(eng.pool.lock().unwrap().stats().allocated_pages, 0,
               "{tag}: pool pages leaked after the storm");
}

/// The deterministic signature contract 10 pins across the worker/fuse
/// grid: per-request tokens, completion order, virtual makespan bits,
/// and every elastic decision counter.
type ChaosSignature = (Vec<(RequestId, Vec<u32>)>, Vec<RequestId>, u64,
                       [u64; 6]);

fn signature(report: &EngineReport) -> ChaosSignature {
    (tokens_by_id(&report.results),
     report.completion_order.clone(),
     report.makespan.to_bits(),
     [report.metrics.shed_rejected,
      report.metrics.shed_degraded,
      report.metrics.priority_boosts,
      report.metrics.spike_peak_queue_depth,
      report.metrics.preemptions,
      report.metrics.requests_cancelled])
}

fn crowd_spec() -> FlashCrowdSpec {
    FlashCrowdSpec { base_requests: 10, base_rate: 20.0,
                     spike_multiplier: 15.0, spike_requests: 20,
                     spike_start: 0.2,
                     prompt_len: LenDist::Uniform(2, 4),
                     gen_len: LenDist::Fixed(4),
                     seed: 0xC4A05 }
}

// contract:10 chaos survivability — shed decisions bit-identical
#[test]
fn flash_crowd_with_degrade_is_bit_identical_across_grid() {
    // a 15x Batch-class spike on top of Interactive chat, shed policy
    // degrade: nothing is dropped, overflow is demoted to Background,
    // and the whole storm — tokens, order, makespan, every shed
    // decision — reproduces bit-for-bit across workers 1/4 x fuse
    let scenario = flash_crowd(&crowd_spec());
    let run = |workers: usize, fuse: bool| {
        let eng = engine();
        let mut c = cfg(24, workers, fuse); // 96-row budget
        c.shed_policy = ShedPolicy::Degrade;
        c.shed_queue_depth = 8;
        c.age_steps = 10;
        let report = run_chaos(&eng, &c, &scenario, model())
            .expect("chaos run failed");
        assert_pool_drained(&eng, "flash-crowd degrade");
        signature(&report)
    };
    let reference = run(1, false);
    for (workers, fuse) in [(1, true), (4, false), (4, true)] {
        assert_eq!(run(workers, fuse), reference,
                   "workers={workers} fuse={fuse}: chaos run diverged");
    }
    // degrade never drops work: all 30 requests complete, and the
    // Interactive tier is never demoted while Batch overflow exists
    let (tokens, _, _, counters) = reference;
    assert_eq!(tokens.len(), 30, "degrade must not drop requests");
    for (id, toks) in &tokens {
        assert_eq!(toks.len(), 4, "request {id} did not finish its gen");
    }
    assert!(counters[1] > 0, "the spike must trigger degradation");
    assert_eq!(counters[0], 0, "degrade must never reject");
    assert!(counters[3] > 8, "peak queue depth must exceed the shed \
                              threshold during the spike");
}

#[test]
fn flash_crowd_with_reject_sheds_deterministically() {
    // same crowd, shed policy reject: the youngest spike entries are
    // rejected; the rejected SET is part of the deterministic signature,
    // the Interactive tier survives intact, and every request that WAS
    // served emits unloaded-identical tokens (contract 10, clause 1)
    let scenario = flash_crowd(&crowd_spec());
    let run = |workers: usize, fuse: bool| {
        let eng = engine();
        let mut c = cfg(24, workers, fuse);
        c.shed_policy = ShedPolicy::Reject;
        c.shed_queue_depth = 6;
        let report = run_chaos(&eng, &c, &scenario, model())
            .expect("chaos run failed");
        assert_pool_drained(&eng, "flash-crowd reject");
        (report, eng, c)
    };
    let (reference, eng, c) = run(1, false);
    let ref_sig = signature(&reference);
    for (workers, fuse) in [(1, true), (4, false), (4, true)] {
        let (report, _eng, _c) = run(workers, fuse);
        assert_eq!(signature(&report), ref_sig,
                   "workers={workers} fuse={fuse}: shed decisions \
                    diverged");
    }
    assert!(reference.metrics.shed_rejected > 0,
            "the spike must overflow the shed threshold");
    assert_eq!(reference.results.len(), 30,
               "every request needs a terminal result");
    let mut completed = 0;
    for r in &reference.results {
        match r.status {
            Outcome::Completed => completed += 1,
            Outcome::Rejected => {
                assert!(r.id >= SPIKE_ID_BASE,
                        "Interactive request {} was shed while Batch \
                         overflow existed", r.id);
                assert!(r.tokens.is_empty(),
                        "a queue-shed victim never decoded");
            }
            Outcome::Cancelled => panic!("no cancels in this scenario"),
        }
    }
    assert_eq!(completed as u64, reference.metrics.requests_completed);
    assert_eq!(completed + reference.metrics.shed_rejected as usize, 30);
    // clause 1: served tokens are bit-identical to unloaded runs
    let diverged = diverged_from_unloaded(&eng, &c, &reference,
                                          &scenario.script, model())
        .expect("reference runs failed");
    assert!(diverged.is_empty(),
            "requests {diverged:?} diverged from their unloaded runs");
}

#[test]
fn cancel_storm_returns_pool_and_budget_to_zero() {
    // satellite 1: cancel every request (queued tails, mid-chunk
    // prefills, mid-decode actives) inside one step-window, then prove
    // the budget is exactly whole again by admitting a request sized to
    // the entire 48-row pool budget
    let spec = CancelStormSpec { requests: 12, cancel_at_step: 3,
                                 survivors: 2,
                                 prompt_len: LenDist::Uniform(3, 9),
                                 gen_len: LenDist::Fixed(8),
                                 seed: 0xCA4CE1 };
    let mut script = cancel_storm(&spec).script;
    let drain = script.pop().expect("generator always ends with Drain");
    // full-budget probe: 40 prompt + 8 gen = 48 rows = the whole budget
    let probe = DecodeRequest::new(9000,
                                   (0..40).map(|i| 700 + i).collect(), 8);
    script.push(ScriptedCommand::after_steps(
        spec.cancel_at_step + 1,
        SessionAction::Submit(vec![SessionSubmit::new(probe)])));
    script.push(drain);

    let run = |workers: usize, fuse: bool| {
        let eng = engine();
        let mut c = cfg(12, workers, fuse); // 48-row budget
        c.prefill_chunk = 2; // 3..9-token prompts are mid-prefill at step 3
        let report = run_scripted(&eng, &c,
                                  &mut SimClock::simulated(model()),
                                  script.clone())
            .expect("cancel storm failed");
        assert_pool_drained(&eng, "cancel storm");
        (signature(&report), report)
    };
    let (ref_sig, report) = run(1, false);
    for (workers, fuse) in [(1, true), (4, false), (4, true)] {
        assert_eq!(run(workers, fuse).0, ref_sig,
                   "workers={workers} fuse={fuse}: cancel storm diverged");
    }
    assert_eq!(report.results.len(), 13);
    let storm_cancelled = report.results.iter()
        .filter(|r| r.status == Outcome::Cancelled)
        .count();
    assert_eq!(storm_cancelled, 10, "all but the survivors cancel");
    let probe_result = report.results.iter().find(|r| r.id == 9000)
        .expect("probe result missing");
    assert_eq!(probe_result.status, Outcome::Completed,
               "the full-budget probe must admit — a single leaked row \
                would block it");
    assert_eq!(probe_result.tokens.len(), 8);
    for id in [10, 11] {
        let r = report.results.iter().find(|r| r.id == id)
            .expect("survivor result missing");
        assert_eq!(r.status, Outcome::Completed,
                   "survivor {id} must finish untouched");
        assert_eq!(r.tokens.len(), 8);
    }
}

#[test]
fn cancel_storm_drops_prefix_pinned_reservations() {
    // satellite 1, prefix edge: a QUEUED request holding a prefix-cache
    // reservation (pinned by a failed admission probe) is cancelled —
    // the pinned pages must return, proven again by a full-budget probe
    let shared: Vec<u32> = (0..16).map(|i| 40 + i).collect(); // 2 pages
    let script = vec![
        // opener publishes the shared 16-token prefix on completion
        ScriptedCommand::immediately(SessionAction::Submit(vec![
            SessionSubmit::new(DecodeRequest::new(0, shared.clone(), 2)),
        ])),
        // once it is done: two fillers crowd the pool (32 + 14 rows of
        // the 48 budget), then a follow-up extending the shared prefix
        // queues behind them and pins a reservation at its admit probe
        ScriptedCommand::after_steps(8, SessionAction::Submit(vec![
            SessionSubmit::new(DecodeRequest::new(
                1, vec![201, 202], 30)),                  // 32 rows
            SessionSubmit::new(DecodeRequest::new(
                2, vec![203, 204], 12)),                  // 14 rows
            SessionSubmit::new(DecodeRequest::new(
                3, [shared.as_slice(), &[205, 206]].concat(), 4)),
        ])),
        // the storm: every live request cancelled in one step-window —
        // request 3 still queued with its reservation, 1 and 2 mid-decode
        ScriptedCommand::after_steps(12, SessionAction::Cancel(3)),
        ScriptedCommand::after_steps(12, SessionAction::Cancel(1)),
        ScriptedCommand::after_steps(12, SessionAction::Cancel(2)),
        // full-budget probe: admits only if every row (including the
        // pinned reservation) was credited back
        ScriptedCommand::after_steps(14, SessionAction::Submit(vec![
            SessionSubmit::new(DecodeRequest::new(
                4, (0..40).map(|i| 900 + i).collect(), 8)),
        ])),
        ScriptedCommand::immediately(SessionAction::Drain),
    ];
    let eng = engine();
    let c = cfg(12, 2, true); // 48-row budget, prefix cache on
    let report = run_scripted(&eng, &c, &mut SimClock::simulated(model()),
                              script)
        .expect("prefix-pin storm failed");
    assert_pool_drained(&eng, "prefix-pin cancel storm");
    let by_id: std::collections::BTreeMap<RequestId, &DecodeResult> =
        report.results.iter().map(|r| (r.id, r)).collect();
    assert_eq!(by_id[&0].status, Outcome::Completed, "opener");
    for id in [1, 2, 3] {
        assert_eq!(by_id[&id].status, Outcome::Cancelled,
                   "request {id} must be storm-cancelled");
    }
    assert_eq!(by_id[&4].status, Outcome::Completed,
               "full-budget probe blocked — a pinned prefix reservation \
                leaked");
    assert_eq!(by_id[&4].tokens.len(), 8);
}

#[test]
fn slow_consumer_flood_completes_every_request() {
    // satellite 2 (chaos tier): 150 capacity-1 streams, 15 drained one
    // token each, 135 abandoned outright — the engine must not wedge on
    // the stalled buffers, must answer a mid-flood metrics snapshot
    // (asserted inside the helper), and every request must still reach
    // a Completed terminal result at shutdown
    let dims = MlaDims { d_model: 48, n1: 2, d_head: 12, q_rank: 24,
                         d_latent: 16, d_rope: 8, sq: 1 };
    let exec = HostLayerExecutor::new(dims, 2, Algo::Amla, 32,
                                      vec![32, 64], 11);
    let config = amla::config::EngineConfig::builder()
        .pool_pages(64)
        .page_size(8)
        .max_batch(8)
        .batch_workers(2)
        .build()
        .expect("valid engine config");
    let report = slow_consumer_flood(config, exec, 150, 10)
        .expect("flood run failed");
    assert_eq!(report.results.len(), 150, "requests lost in the flood");
    assert_eq!(report.metrics.requests_completed, 150);
    for r in &report.results {
        assert_eq!(r.status, Outcome::Completed,
                   "request {} did not complete", r.id);
        assert_eq!(r.tokens.len(), 4,
                   "request {} lost tokens to a stalled stream", r.id);
    }
    assert_eq!(report.completion_order.len(), 150);
}

#[test]
fn repeated_preemption_of_one_victim_is_bit_identical() {
    // satellite 3: a flash crowd that evicts the SAME Background victim
    // at least three times; the ResumeLedger's merged result — tokens,
    // TTFT, queue delay — must be bit-identical to the unconstrained
    // (never-preempted) run of the same scenario
    let scenario = repeat_evict_crowd(&RepeatEvictSpec::default());
    let run = |pool_pages: usize| {
        let eng = engine();
        let report = run_chaos(&eng, &cfg(pool_pages, 2, true), &scenario,
                               model())
            .expect("repeat-evict run failed");
        assert_pool_drained(&eng, "repeat evict");
        report
    };
    // 48-row budget: the 44-row victim and a 6-row wave cannot coexist
    let constrained = run(12);
    assert!(constrained.metrics.preemptions >= 3,
            "need >= 3 evictions of the one eligible victim, got {}",
            constrained.metrics.preemptions);
    assert_eq!(constrained.batcher.preempted,
               constrained.metrics.preemptions);
    let unconstrained = run(128);
    assert_eq!(unconstrained.metrics.preemptions, 0,
               "the wide pool must never preempt");
    assert_eq!(tokens_by_id(&constrained.results),
               tokens_by_id(&unconstrained.results),
               "merged token streams diverged across >= 3 evictions");
    let victim = |r: &EngineReport| {
        r.results.iter().find(|x| x.id == VICTIM_ID)
            .map(|x| (x.ttft.to_bits(), x.queue_delay.to_bits(),
                      x.status))
            .expect("victim result missing")
    };
    // TTFT and queue delay stem from the victim's FIRST admission —
    // the ledger must carry them across every eviction untouched
    assert_eq!(victim(&constrained), victim(&unconstrained),
               "ledger merge corrupted the victim's TTFT/queue-delay");
}

#[test]
fn long_context_mix_survives_split_kv_and_prefix_cache() {
    // 96-token prompts (Background) prefilling in chunks while bursty
    // Interactive chat flows around them; split-KV partitions the long
    // decode block loops.  Grid-identical, unloaded-identical, drained.
    let spec = LongContextMixSpec { long_requests: 2, context: 96,
                                    long_gen: 6, chat_requests: 8,
                                    chat_rate: 10.0, seed: 0x10C7 };
    let scenario = long_context_mix(&spec);
    // wider shape buckets than the default harness: a 96-token context
    // plus its generation must fit the largest bucket
    let long_engine = || {
        let dims = MlaDims { d_model: 48, n1: 2, d_head: 12, q_rank: 24,
                             d_latent: 16, d_rope: 8, sq: 1 };
        let exec = HostLayerExecutor::new(dims, 2, Algo::Amla, 32,
                                          vec![64, 128], 11);
        DecodeEngine::new(exec, 512, 8)
    };
    let run = |workers: usize, fuse: bool| {
        let eng = long_engine();
        let c = cfg(64, workers, fuse); // 256-row budget
        let report = run_chaos(&eng, &c, &scenario, model())
            .expect("long-context mix failed");
        assert_pool_drained(&eng, "long-context mix");
        (report, eng, c)
    };
    let (reference, eng, c) = run(1, false);
    let ref_sig = signature(&reference);
    for (workers, fuse) in [(4, false), (4, true)] {
        assert_eq!(signature(&run(workers, fuse).0), ref_sig,
                   "workers={workers} fuse={fuse}: mix diverged");
    }
    assert_eq!(reference.results.len(), 10);
    for r in &reference.results {
        assert_eq!(r.status, Outcome::Completed, "request {} lost", r.id);
    }
    let diverged = diverged_from_unloaded(&eng, &c, &reference,
                                          &scenario.script, model())
        .expect("reference runs failed");
    assert!(diverged.is_empty(),
            "requests {diverged:?} diverged from their unloaded runs");
}

#[test]
fn pool_churn_with_prefix_cache_drains_and_reuses_pages() {
    // shared-prefix waves against an 80-row budget with a cancel per
    // wave: prefix pages are published, hit, pinned, and released under
    // constant churn; later waves must actually HIT the prefix cache,
    // and the pool must drain to zero regardless
    let spec = PoolChurnSpec { waves: 3, per_wave: 4, prefix_len: 16,
                               gen_len: 6, wave_gap: 0.4, seed: 0xC0FF };
    let scenario = pool_churn(&spec);
    let run = |workers: usize, fuse: bool| {
        let eng = engine();
        let report = run_chaos(&eng, &cfg(20, workers, fuse), &scenario,
                               model())
            .expect("pool churn failed");
        assert_pool_drained(&eng, "pool churn");
        report
    };
    let reference = run(1, false);
    let ref_sig = signature(&reference);
    for (workers, fuse) in [(1, true), (4, false), (4, true)] {
        assert_eq!(signature(&run(workers, fuse)), ref_sig,
                   "workers={workers} fuse={fuse}: churn diverged");
    }
    assert_eq!(reference.results.len(), 12,
               "every churn request needs a terminal result");
    assert!(reference.metrics.prefix_hits > 0,
            "later waves must hit the shared prefix");
    for r in &reference.results {
        assert!(r.status == Outcome::Completed
                    || r.status == Outcome::Cancelled,
                "request {} ended {:?}", r.id, r.status);
    }
}

#[test]
fn aging_rescues_background_from_a_batch_flood() {
    // a Background request behind a sustained Batch flood: without
    // aging it finishes dead last; with age_steps=6 it is promoted into
    // the Batch FIFO after ~6 steps of starvation and overtakes the
    // flood's tail — and the boost decision is grid-deterministic
    let mut subs = vec![
        SessionSubmit::new(DecodeRequest::new(500, vec![3, 4], 4))
            .at(0.0)
            .priority(Priority::Background),
    ];
    for i in 0..12u64 {
        subs.push(SessionSubmit::new(
                DecodeRequest::new(i, vec![10 + i as u32, 11], 4))
            .at(i as f64 * 0.04)
            .priority(Priority::Batch));
    }
    let script = vec![
        ScriptedCommand::immediately(SessionAction::Submit(subs)),
        ScriptedCommand::immediately(SessionAction::Drain),
    ];
    let run = |workers: usize, age_steps: u64| {
        let eng = engine();
        let mut c = cfg(64, workers, true);
        c.max_batch = 1; // serialize so the flood genuinely starves
        c.age_steps = age_steps;
        let report = run_scripted(&eng, &c,
                                  &mut SimClock::simulated(model()),
                                  script.clone())
            .expect("aging run failed");
        assert_pool_drained(&eng, "aging flood");
        report
    };
    let aged = run(1, 6);
    assert_eq!(aged.metrics.priority_boosts, 1,
               "exactly one Background entry crosses the horizon");
    let pos = |r: &EngineReport, id: RequestId| {
        r.completion_order.iter().position(|&x| x == id)
            .expect("request 500 missing from completion order")
    };
    assert!(pos(&aged, 500) < 12,
            "the boosted request must overtake the flood's tail \
             (finished {} of 13)", pos(&aged, 500) + 1);
    let unaged = run(1, 0);
    assert_eq!(unaged.metrics.priority_boosts, 0);
    assert_eq!(pos(&unaged, 500), 12,
               "without aging, Background waits out the whole flood");
    // grid determinism of the boost decision
    assert_eq!(signature(&run(4, 6)), signature(&aged),
               "aging decisions diverged across workers");
    // aging reschedules, never rewrites: token streams match
    assert_eq!(tokens_by_id(&aged.results), tokens_by_id(&unaged.results),
               "aging changed decoded tokens");
}

#[test]
fn chaos_sweep_emits_a_deterministic_envelope() {
    // the `amla chaos` sweep: one engine, ascending spike multipliers,
    // JSON report byte-identical across repeat runs (the BENCH_serving
    // reproducibility requirement)
    let ccfg = ChaosSweepConfig {
        multipliers: vec![8.0, 1.0, 25.0], // unsorted on purpose
        slo_ttft_p99_s: 0.5,
        model: model(),
        base: FlashCrowdSpec { base_requests: 6, base_rate: 15.0,
                               spike_requests: 10, spike_start: 0.2,
                               prompt_len: LenDist::Uniform(2, 3),
                               gen_len: LenDist::Fixed(3),
                               seed: 0x51EE7,
                               ..FlashCrowdSpec::default() },
    };
    let sweep = |_: usize| {
        let eng = engine();
        let mut c = cfg(24, 2, true);
        c.shed_policy = ShedPolicy::Degrade;
        c.shed_queue_depth = 8;
        let report = chaos_sweep(&eng, &c, &ccfg).expect("sweep failed");
        assert_pool_drained(&eng, "chaos sweep");
        report
    };
    let a = sweep(0);
    let b = sweep(1);
    assert_eq!(a.to_json().to_string(), b.to_json().to_string(),
               "chaos sweep is not reproducible");
    let mults: Vec<f64> = a.points.iter().map(|p| p.multiplier).collect();
    assert_eq!(mults, vec![1.0, 8.0, 25.0], "points must sort ascending");
    for p in &a.points {
        assert_eq!(p.base_completed, 6,
                   "degrade must never drop Interactive traffic \
                    (multiplier {})", p.multiplier);
    }
    let parsed = Json::parse(&a.to_json().to_string())
        .expect("sweep JSON must parse");
    assert_eq!(parsed.req_str("metric").unwrap(),
               "chaos_survivable_envelope");
    let table = a.render_table();
    assert!(table.contains("survivable envelope"));
}
